"""Activation / batch / cache PartitionSpec builders.

The paper's throughput discipline as mesh policy: every *population* axis
(training batch, decode request batch, tracker stream axis) shards over
``(pod, data)`` with zero cross-member collectives; model internals shard
over ``model``.

For the SORT serving path the population axis has its own dedicated 1-D
mesh axis, ``"lanes"`` (:data:`LANE_AXIS`): the scheduler's lane budget is
split contiguously over devices with **no** other axis in play, because
the fused frame step never communicates across lanes (DESIGN.md §7).
:func:`lane_dim_spec` builds the one PartitionSpec family every lane-
sharded pytree uses; :mod:`repro.sharding.lanes` maps it onto whole state
and chunk-operand trees.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# The SORT lane axis: one logical mesh axis for the whole serving
# population (DESIGN.md §7).  Sequences are independent, so sharding this
# axis needs zero collectives — the device-level restatement of the
# paper's one-worker-per-video throughput model.
LANE_AXIS = "lanes"


def lane_dim_spec(ndim: int, lane_dim: int) -> P:
    """Spec sharding dimension ``lane_dim`` of a rank-``ndim`` array over
    :data:`LANE_AXIS`, replicating every other dimension."""
    dims = [None] * ndim
    dims[lane_dim] = LANE_AXIS
    return P(*dims)


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _div(n: int, mesh: Mesh, axes: tuple) -> bool:
    return n % int(np.prod([mesh.shape[a] for a in axes], initial=1)) == 0


def batch_spec(shape: tuple, mesh: Mesh) -> P:
    """Shard dim 0 (batch/stream axis) over (pod, data) when divisible."""
    dp = dp_axes(mesh)
    if shape and _div(shape[0], mesh, dp):
        return P(dp if len(dp) > 1 else dp[0], *([None] * (len(shape) - 1)))
    # fall back: try data alone, else replicate
    if shape and "data" in mesh.shape and _div(shape[0], mesh, ("data",)):
        return P("data", *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_pspecs(batch_tree, mesh: Mesh):
    import jax
    return jax.tree.map(lambda x: batch_spec(x.shape, mesh), batch_tree)


def cache_spec(shape: tuple, mesh: Mesh) -> P:
    """KV/SSM cache: batch dim 0 over (pod, data); if batch=1 (long-context)
    shard the sequence dim over data; head-like dims over model when they
    divide."""
    dims = [None] * len(shape)
    dp = dp_axes(mesh)
    used_data = False
    if shape and shape[0] > 1 and _div(shape[0], mesh, dp):
        dims[0] = dp if len(dp) > 1 else dp[0]
        used_data = True
    elif shape and shape[0] > 1 and "data" in mesh.shape \
            and _div(shape[0], mesh, ("data",)):
        dims[0] = "data"
        used_data = True
    if not used_data and len(shape) >= 2 and "data" in mesh.shape \
            and shape[1] % mesh.shape["data"] == 0 and shape[1] >= 1024:
        dims[1] = "data"  # long-context: shard cache sequence
    # shard one more dim over model if a head/width-like dim divides
    if "model" in mesh.shape:
        for d in range(len(shape) - 1, 0, -1):
            if dims[d] is None and shape[d] % mesh.shape["model"] == 0 \
                    and shape[d] >= mesh.shape["model"]:
                dims[d] = "model"
                break
    return P(*dims)


def cache_pspecs(cache_tree, mesh: Mesh, has_layer_dim: bool = True):
    """Specs for a stacked cache pytree (leaves [L, B, ...] or [B, ...])."""
    import jax

    def leaf(x):
        shape = x.shape
        if has_layer_dim and len(shape) >= 2:
            inner = cache_spec(shape[1:], mesh)
            return P(None, *inner)
        return cache_spec(shape, mesh)

    return jax.tree.map(leaf, cache_tree)


def named(tree_pspecs, mesh: Mesh):
    import jax
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
