"""Logical-axis -> mesh-axis rules (shape-aware).

Model code annotates every parameter dim with a logical name
(``repro.models.layers``).  This module maps those names onto the mesh:

=========  ==================  =====================================
logical    mesh axis           meaning
=========  ==================  =====================================
embed      data                d_model dim — FSDP (ZeRO-3) sharding
vocab      model               embedding/LM-head vocab — TP
heads      model               fused attention heads — TP
kv         model               fused KV heads — TP
ff         model               MLP hidden — TP
experts    model               MoE expert dim — EP
ff_exp     data                per-expert hidden — FSDP
inner      model               SSM inner width — TP
lora       None                MLA latent ranks (small, replicated)
stream     lanes               SORT serving lane axis — pure throughput
=========  ==================  =====================================

``stream`` is the tracking service's population axis (DESIGN.md §7): the
scheduler's lane budget over a dedicated 1-D ``("lanes",)`` mesh.  It
never mixes with ``data``/``model`` because the SORT frame step has no
cross-lane term — device parallelism is plain replication of independent
per-lane programs (``repro.sharding.lanes``).

Rules are *shape-aware*: a dim whose size does not divide the mapped mesh
axes falls back to replication (e.g. qwen2-7b's 28 heads on a 16-way model
axis).  The roofline report surfaces the cost; head-padding is a §Perf
hillclimb, not silently forced.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES = {
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "ff": ("model",),
    "experts": ("model",),
    "ff_exp": ("data",),
    "inner": ("model",),
    "lora": (),
    "stream": ("lanes",),
    None: (),
}


def _axes_size(mesh: Mesh, axes: tuple) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], initial=1))


def spec_for_logical(logical: tuple, shape: tuple, mesh: Mesh,
                     rules=None) -> P:
    """Build a PartitionSpec for one param from its logical axes + shape."""
    rules = rules or LOGICAL_RULES
    used = set()
    parts = []
    for dim, name in enumerate(logical):
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.shape
                     and a not in used)
        if axes and shape[dim] % _axes_size(mesh, axes) == 0:
            parts.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            parts.append(None)
    return P(*parts)


def params_pspecs(specs_tree, shapes_tree, mesh: Mesh, rules=None):
    """Map a (specs, shapes) pytree pair to PartitionSpecs.

    ``specs_tree`` leaves are logical-axis tuples; ``shapes_tree`` leaves are
    ShapeDtypeStructs (or arrays) with matching structure.
    """
    flat_specs, treedef = jax.tree.flatten(
        specs_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_shapes = treedef.flatten_up_to(shapes_tree)
    out = [spec_for_logical(sp, np.shape(sh) if not hasattr(sh, "shape")
                            else sh.shape, mesh, rules)
           for sp, sh in zip(flat_specs, flat_shapes)]
    return jax.tree.unflatten(treedef, out)


def params_shardings(specs_tree, shapes_tree, mesh: Mesh, rules=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(specs_tree, shapes_tree, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))
