"""Mesh policy: logical-axis rules, activation specs, and the SORT lane axis.

``rules``/``specs`` cover the LM stack (FSDP/TP/EP); ``lanes`` is the
tracking service's device-parallel serving layer — the scheduler's lane
budget sharded over a 1-D ``("lanes",)`` mesh with zero collectives
(DESIGN.md §7).  The ``lanes`` symbols resolve lazily so LM-stack callers
(``launch/train.py`` imports ``rules`` at startup) never pay the
tracking-core import, and an import-time failure in one stack cannot
break the other.
"""
from .rules import LOGICAL_RULES, spec_for_logical, params_pspecs  # noqa: F401
from .specs import (LANE_AXIS, batch_pspecs, cache_pspecs,  # noqa: F401
                    lane_dim_spec, named)

_LANES_EXPORTS = ("LaneSharding", "MeshLaneState", "lane_mesh",
                  "shard_count", "state_pspecs")


def __getattr__(name):
    if name in _LANES_EXPORTS:
        from . import lanes
        return getattr(lanes, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LANES_EXPORTS))
