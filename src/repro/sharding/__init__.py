from .rules import LOGICAL_RULES, spec_for_logical, params_pspecs  # noqa: F401
from .specs import batch_pspecs, cache_pspecs, named  # noqa: F401
