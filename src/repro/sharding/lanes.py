"""Device-sharded lane serving — the lane axis spread over a JAX mesh.

The paper's throughput result (§VI) is that tiny-matrix SORT scales only
by running *independent* video sequences in parallel — one OpenMP worker
per stream there, one vector lane per stream here (DESIGN.md §2).  A
single device caps the lane budget; this module adds the next rung
(DESIGN.md §7): shard the lane axis over a 1-D ``("lanes",)`` device mesh
so one :class:`~repro.serve.StreamScheduler` drives N devices, each
running the same single-dispatch fused frame step on its own contiguous
lane shard.

Because sequences are independent — no phase of the frame step ever
crosses lanes (DESIGN.md §3.2) — the sharded program needs **zero
cross-device collectives**: ``shard_map`` (via :mod:`repro.compat`)
partitions the state and chunk operands, every device scans its shard
locally, and a sharded run is *bit-identical* to the single-device run
(``tests/test_device_sharding.py`` locks this down for both engine paths
and both association modes).  The chunk-resident megakernel (DESIGN.md
§9) composes unchanged: ``run_chunk_ragged`` replaces the per-frame scan
inside the ``shard_map`` body with one chunk dispatch per device, still
collective-free (same HLO grep lock, ``chunk_kernel=True`` case).

Sharding layouts (the lane axis must be a contiguous array dimension for
``NamedSharding`` to place each device's shard without copies):

* per-phase path — :class:`~repro.core.SortState`: the stream axis is
  dim 0 of every leaf (``x [L, T, 7]``, pool fields ``[L, T]``), so the
  state shards directly.
* fused path — :class:`~repro.core.LaneSortState` flattens lanes
  tracker-slot major (``b = t * S_pad + s``), so a contiguous split of
  ``[7, B]`` would cut the *slot* axis, not the stream axis.  The sharded
  resident state therefore keeps the free 3-D view
  (:class:`MeshLaneState`: ``x [7, T, L]``, ``p [49, T, L]``) whose minor
  axis *is* the lane axis; each device's shard reshapes back to a local
  ``LaneSortState`` at zero cost inside the ``shard_map`` body.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import kalman, slots
from repro.core.sort import (LaneSortState, SortOutput, SortState,
                             lane_state_of, resize_streams, sort_state_of)

from .specs import LANE_AXIS, lane_dim_spec, named

__all__ = ["LANE_AXIS", "MeshLaneState", "LaneSharding", "lane_mesh",
           "shard_count", "state_pspecs"]


def lane_mesh(num_devices: Optional[int] = None, *, devices=None) -> Mesh:
    """A 1-D ``("lanes",)`` mesh over the first ``num_devices`` devices.

    On CPU, simulated devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes); the error message below points there because it is the
    step everyone forgets.
    """
    devs = list(devices) if devices is not None else jax.devices()
    if num_devices is not None:
        if num_devices > len(devs):
            raise ValueError(
                f"requested {num_devices} devices, only {len(devs)} "
                f"available (on CPU, set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={num_devices} "
                f"before jax initializes)")
        devs = devs[:num_devices]
    return Mesh(np.asarray(devs), (LANE_AXIS,))


def shard_count(mesh: Mesh) -> int:
    if LANE_AXIS not in mesh.shape:
        raise ValueError(
            f"mesh {mesh.axis_names} has no {LANE_AXIS!r} axis; build it "
            f"with repro.sharding.lane_mesh")
    return int(mesh.shape[LANE_AXIS])


class MeshLaneState(NamedTuple):
    """:class:`~repro.core.LaneSortState` in its free 3-D view, lane-minor.

    ``x [7, T, L]`` / ``p [49, T, L]`` are row-major reshapes of the flat
    ``[7, B]`` / ``[49, B]`` lane state (``B = T * L``), so converting
    between the two is free *per shard*; ``pool`` fields are already
    ``[T, L]`` and ``frame_count`` ``[L]``.  Every leaf carries the lane
    axis as its **last** dimension, which is what lets one
    ``PartitionSpec`` family shard the whole pytree contiguously.
    """

    x: jnp.ndarray            # [7, T, L]
    p: jnp.ndarray            # [49, T, L]
    pool: slots.SlotPool      # [T, L] (+ next_uid [L])
    frame_count: jnp.ndarray  # [L]
    # [E, T, L] appearance embeddings (zero-size when the cost has no
    # embed term, DESIGN.md §10); lane axis last like every other leaf
    embed: jnp.ndarray = None


def mesh_view(lane: LaneSortState) -> MeshLaneState:
    """Flat lane state -> 3-D mesh view (free row-major reshape)."""
    t, sp = lane.pool.alive.shape
    e = lane.embed.shape[0]
    return MeshLaneState(
        x=lane.x.reshape(kalman.DIM_X, t, sp),
        p=lane.p.reshape(49, t, sp),
        pool=lane.pool,
        frame_count=lane.frame_count,
        embed=lane.embed.reshape(e, t, sp))


def lane_view(mesh_state: MeshLaneState) -> LaneSortState:
    """3-D mesh view -> flat lane state (the engine's resident layout)."""
    t, sp = mesh_state.pool.alive.shape
    e = mesh_state.embed.shape[0]
    return LaneSortState(
        x=mesh_state.x.reshape(kalman.DIM_X, t * sp),
        p=mesh_state.p.reshape(49, t * sp),
        pool=mesh_state.pool,
        frame_count=mesh_state.frame_count,
        embed=mesh_state.embed.reshape(e, t * sp))


def state_pspecs(state):
    """PartitionSpecs sharding a state pytree's lane axis over ``lanes``.

    :class:`MeshLaneState` carries the lane axis last on every leaf;
    :class:`~repro.core.SortState` carries it first.  Either way one
    uniform rule specs the whole tree.
    """
    if isinstance(state, MeshLaneState):
        return jax.tree.map(lambda a: lane_dim_spec(a.ndim, a.ndim - 1),
                            state)
    if isinstance(state, SortState):
        return jax.tree.map(lambda a: lane_dim_spec(a.ndim, 0), state)
    raise TypeError(f"unshardable state type {type(state).__name__}; "
                    f"expected MeshLaneState or SortState")


# chunk operands are [chunk, L, ...]: the lane axis is dim 1 everywhere
def _chunk_spec(ndim: int) -> P:
    return lane_dim_spec(ndim, 1)


class LaneSharding:
    """``lanes -> mesh`` sharding layer for the stream scheduler.

    Wraps the scheduler's chunked ``lax.scan`` in ``shard_map`` over a
    1-D ``("lanes",)`` mesh: each device owns ``num_lanes / N`` contiguous
    lanes of the resident state and steps them with the engine's own
    ``step_ragged`` — the same single fused dispatch per device per scan
    step, no collectives, host-side planning untouched.

    Usage (what ``StreamScheduler(mesh=...)`` does internally)::

        sharding = LaneSharding(engine, mesh, num_lanes)
        state = sharding.init()                     # device_put, sharded
        chunk = jax.jit(sharding.shard_chunk(body)) # body = reset+step scan
        det, dm, act, rst = sharding.place(det, dm, act, rst)
        state, outs = chunk(state, det, dm, act, rst)
    """

    def __init__(self, engine, mesh: Mesh, num_lanes: int):
        n = shard_count(mesh)
        if num_lanes % n != 0:
            raise ValueError(
                f"num_lanes={num_lanes} must divide evenly over the "
                f"{n}-device lane mesh (got remainder {num_lanes % n})")
        self.engine = engine
        self.mesh = mesh
        self.num_lanes = num_lanes
        self.shard_count = n
        self.lanes_per_shard = num_lanes // n
        self._fused = bool(engine.config.use_kernels)
        self._state_specs = None

    # ----------------------------------------------------------- state init
    def init(self):
        """Sharded initial ragged state, placed with ``NamedSharding``.

        The init state is lane-uniform (zero means, broadcast covariance,
        empty pool), so the global state is ``shard_count`` tiled copies of
        a per-shard ``init_ragged`` — bit-identical to what each device
        would initialize locally, including the fused path's per-shard
        stream padding.
        """
        if self._fused:
            local = mesh_view(self.engine.init_ragged(self.lanes_per_shard))
            state = jax.tree.map(
                lambda a: jnp.tile(
                    a, (1,) * (a.ndim - 1) + (self.shard_count,)), local)
        else:
            state = self.engine.init(self.num_lanes)
        self._state_specs = state_pspecs(state)
        return jax.device_put(state, named(self._state_specs, self.mesh))

    # ------------------------------------------------------------ chunk fn
    def shard_chunk(self, chunk_body, extra_operand_ndims=()):
        """Wrap the scheduler's chunk scan in ``shard_map``.

        ``chunk_body(state, det, dm, active, reset, *extras) -> (state,
        outs)`` is the unsharded scan (masked re-init + ``step_ragged`` per
        step); it runs unchanged on each device's local lane shard.
        ``extra_operand_ndims`` declares the rank of each trailing operand
        (e.g. ``det_class [C, L, D]`` -> 3, ``det_embed [C, L, D, E]`` ->
        4); like every chunk operand they carry the lane axis on dim 1, so
        the class/embed threading stays collective-free (DESIGN.md §10).
        On the fused path the carried state crosses the boundary in its 3-D
        mesh view and reshapes to the flat local lane layout inside — both
        reshapes are free.  No collective appears anywhere in the body, so
        the compiled program is N independent per-device scans.
        """
        if self._state_specs is None:
            raise RuntimeError("call init() before shard_chunk()")
        fused = self._fused

        def local_chunk(state, det, dm, active, reset, *extras):
            st = lane_view(state) if fused else state
            st, outs = chunk_body(st, det, dm, active, reset, *extras)
            return (mesh_view(st) if fused else st), outs

        out_specs = (self._state_specs,
                     SortOutput(boxes=_chunk_spec(4), uid=_chunk_spec(3),
                                emit=_chunk_spec(3), matched_det=_chunk_spec(3),
                                cls=_chunk_spec(3)))
        return compat.shard_map(
            local_chunk, self.mesh,
            in_specs=(self._state_specs, _chunk_spec(4), _chunk_spec(3),
                      _chunk_spec(2), _chunk_spec(2))
                     + tuple(_chunk_spec(n) for n in extra_operand_ndims),
            out_specs=out_specs,
            check_vma=False)

    # ----------------------------------------------------------- migration
    def _to_engine(self, state):
        """Sharded resident state -> global engine-layout :class:`SortState`
        holding exactly this sharding's real lanes, in global lane order.

        The fused :class:`MeshLaneState` interleaves per-shard stream
        padding with real lanes (each device's block is ``lanes_per_shard``
        real lanes padded to the kernel's stream block), so the lane-minor
        axis is walked shard by shard and each shard's padding dropped via
        the exact :func:`repro.core.sort.sort_state_of` inverse.
        """
        if not self._fused:
            return state
        sp_local = state.frame_count.shape[0] // self.shard_count
        parts = []
        for s in range(self.shard_count):
            local = jax.tree.map(
                lambda a, s=s: a[..., s * sp_local:(s + 1) * sp_local],
                state)
            parts.append(sort_state_of(lane_view(local),
                                       self.lanes_per_shard))
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def _from_engine(self, eng_state):
        """Global engine-layout state -> this sharding's resident layout
        (re-inserting the fused path's per-shard stream padding)."""
        if not self._fused:
            return eng_state
        lps = self.lanes_per_shard
        parts = []
        for s in range(self.shard_count):
            local = jax.tree.map(lambda a, s=s: a[s * lps:(s + 1) * lps],
                                 eng_state)
            parts.append(mesh_view(lane_state_of(
                local, self.engine._block_s)))
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=-1), *parts)

    def migrate(self, state, new_sharding: "LaneSharding"):
        """Move the resident state to ``new_sharding``'s lane budget
        (DESIGN.md §8) — same mesh, different width.

        The state crosses widths through the global engine layout using
        the exact layout inverses, so every kept lane (including lanes
        mid-sequence) is bit-identical after the move; appended lanes are
        freshly re-initialised (``core.sort.resize_streams``).  The result
        is re-placed with the new width's ``NamedSharding`` **here**, at
        the chunk boundary — the jitted chunk scan always starts from
        committed lane shardings and never pays a resharding copy
        mid-chunk (``tests/test_autoscale.py`` asserts the placement).
        """
        if new_sharding.mesh is not self.mesh \
                and new_sharding.mesh != self.mesh:
            raise ValueError("migrate() moves state between widths of the "
                             "same mesh, not between meshes")
        eng_state = resize_streams(self._to_engine(state),
                                   new_sharding.num_lanes)
        return new_sharding.place_engine_state(eng_state)

    def place_engine_state(self, eng_state):
        """Global engine-layout state (``num_lanes`` streams) -> this
        sharding's resident layout, placed with its ``NamedSharding`` —
        the entry point for restoring a topology-neutral checkpoint onto
        this mesh (DESIGN.md §11) and the commit half of :meth:`migrate`."""
        new_state = self._from_engine(eng_state)
        self._state_specs = state_pspecs(new_state)
        return jax.device_put(new_state,
                              named(self._state_specs, self.mesh))

    # ----------------------------------------------------------- placement
    def place(self, det, dm, active, reset, *extras):
        """Host chunk operands -> device, already lane-sharded.

        ``device_put`` with the matching ``NamedSharding`` scatters each
        host array straight to its owning devices, so the jitted chunk
        consumes committed shardings and never inserts a resharding copy.
        Trailing ``extras`` (``det_class`` / ``det_embed``) are placed by
        the same lane-on-dim-1 rule.
        """
        arrs = (det, dm, active, reset) + extras
        return tuple(
            jax.device_put(np.asarray(a),
                           NamedSharding(self.mesh, _chunk_spec(a.ndim)))
            for a in arrs)
