"""Version-compat shims so the repo runs on every supported jax.

The sharded training/MoE paths target the modern ``jax.shard_map`` API
(``check_vma`` / ``axis_names``); older jax only has
``jax.experimental.shard_map.shard_map`` (``check_rep`` / ``auto``).
This module maps one onto the other:

* ``axis_names={...}``  (manual axes, new API)  ->  ``auto = mesh axes -
  axis_names`` (old API names the *automatic* complement instead).
* ``check_vma``  ->  ``check_rep`` (same replication check, renamed).
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names=None):
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
