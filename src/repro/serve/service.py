"""Tracking service front-end — admission, backpressure, crash recovery.

:class:`~repro.serve.scheduler.StreamScheduler` answers *how* ragged
sequences share the engine's lanes; this module answers what stands
between that scheduler and the network (DESIGN.md §11):

* **Admission control** — ``submit`` is async and *bounded*: a global
  in-flight cap plus a per-client cap, with an optional per-client token
  bucket.  Over-budget submissions are shed **explicitly** with
  :class:`Overloaded` carrying a ``retry_after`` hint — the queue never
  grows without bound, and a client is told when to come back instead of
  being silently stalled.
* **Circuit breaker** — device dispatch is wrapped in a
  CLOSED / OPEN / HALF_OPEN breaker: repeated chunk failures open it
  (submissions and steps shed fast instead of hammering a sick
  accelerator), a timed half-open probe retries one chunk, and success
  closes it again.  A failed chunk's host planning is rolled back from
  the latest checkpoint so the probe retries the *same* work.
* **Crash-exact checkpoint/restore** — at chunk boundaries the service
  snapshots the scheduler's complete state (``export_state``) plus its
  own delivery/accounting state through :mod:`repro.ckpt`.  Results are
  delivered **before** the covering checkpoint commits (at-least-once:
  a crash between delivery and commit re-delivers, never loses), so a
  SIGKILL'd server resumed with :meth:`TrackingService.resume` produces
  per-sequence outputs **bit-identical** to an uninterrupted run — the
  lane-recycling invariant (DESIGN.md §3) makes both equal the solo run.

Time is injectable (``clock=``) so rate limiting and breaker timeouts
are deterministic under test (tests/test_serving.py).
"""
from __future__ import annotations

import asyncio
import json
import time
from typing import Callable, Optional

import numpy as np

from repro.ckpt import CheckpointManager, committed_steps, restore_flat
from repro.data.stream import SequenceTracks

SERVICE_META_KEY = "__service_meta__"


class Overloaded(Exception):
    """Explicit load shed: the service cannot take this work *right now*.

    ``retry_after`` (seconds) is the backpressure signal — an HTTP
    front-end maps it straight onto a 429/503 ``Retry-After`` header.
    ``reason`` says which limit tripped (``"rate"``, ``"queue"``,
    ``"client_queue"``, ``"breaker_open"``).
    """

    def __init__(self, reason: str, retry_after: float):
        super().__init__(f"overloaded ({reason}); retry after "
                         f"{retry_after:.3f}s")
        self.reason = reason
        self.retry_after = float(retry_after)


class TokenBucket:
    """Per-client admission rate limiter.

    ``rate`` tokens/second refill toward a ``burst`` cap; ``try_take``
    returns ``0.0`` on success or the seconds until a token would be
    available (the ``Retry-After`` hint) — it never sleeps, shedding is
    the caller's policy.
    """

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate and burst must be > 0, got "
                             f"rate={rate}, burst={burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._last = clock()

    def try_take(self, n: float = 1.0) -> float:
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now
        if self._tokens >= n:
            self._tokens -= n
            return 0.0
        return (n - self._tokens) / self.rate


class CircuitBreaker:
    """CLOSED / OPEN / HALF_OPEN breaker around device dispatch.

    ``failure_threshold`` consecutive failures open it; after
    ``reset_timeout`` seconds ``allow()`` grants exactly one half-open
    probe; the probe's success closes the breaker, its failure re-opens
    it (and restarts the timeout).  ``retry_after()`` is the shed hint
    while open.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, failure_threshold: int = 3, reset_timeout: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError(f"failure_threshold must be >= 1, got "
                             f"{failure_threshold}")
        self.failure_threshold = failure_threshold
        self.reset_timeout = float(reset_timeout)
        self._clock = clock
        self.state = self.CLOSED
        self.failures = 0
        self._opened_at = 0.0

    def allow(self) -> bool:
        """May a dispatch proceed right now?  Grants the half-open probe
        as a side effect once the timeout has elapsed."""
        if self.state == self.CLOSED:
            return True
        if self.state == self.OPEN and \
                self._clock() - self._opened_at >= self.reset_timeout:
            self.state = self.HALF_OPEN
            return True
        return self.state == self.HALF_OPEN

    def record_success(self) -> None:
        self.state = self.CLOSED
        self.failures = 0

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == self.HALF_OPEN or \
                self.failures >= self.failure_threshold:
            self.state = self.OPEN
            self._opened_at = self._clock()

    def retry_after(self) -> float:
        if self.state != self.OPEN:
            return 0.0
        return max(0.0, self.reset_timeout -
                   (self._clock() - self._opened_at))


class TrackingService:
    """Async serving front-end over a :class:`StreamScheduler`.

    Usage::

        svc = TrackingService(sched, ckpt_dir="ckpts", rate=100, burst=20)
        idx = await svc.submit("seq-7", det_boxes, det_mask, client="cam7")
        tracks = await svc.result(idx)          # or: await svc.drain()

    ``submit`` resolves immediately (admission is host-side planning);
    the engine advances only through :meth:`step` / :meth:`drain`, which
    dispatch one scheduler chunk at a time, deliver finished sequences
    (futures + ``on_result``), and then checkpoint — every knob of the
    recovery story (delivery order, breaker rollback, resume) lives at
    this chunk granularity.
    """

    def __init__(self, scheduler, *, max_pending: int = 64,
                 per_client_pending: int = 16,
                 rate: Optional[float] = None, burst: Optional[float] = None,
                 breaker_threshold: int = 3, breaker_reset: float = 5.0,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 1,
                 keep: int = 3, retry_after_hint: float = 0.05,
                 on_result: Optional[Callable[[int, SequenceTracks],
                                              None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if per_client_pending < 1:
            raise ValueError(f"per_client_pending must be >= 1, got "
                             f"{per_client_pending}")
        if ckpt_every < 1:
            raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
        self.sched = scheduler
        self.max_pending = max_pending
        self.per_client_pending = per_client_pending
        self.retry_after_hint = retry_after_hint
        self.on_result = on_result
        self._clock = clock
        self._rate = rate
        self._burst = burst if burst is not None else rate
        self._buckets: dict[str, TokenBucket] = {}
        self.breaker = CircuitBreaker(breaker_threshold, breaker_reset,
                                      clock=clock)
        self.ckpt_every = ckpt_every
        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep)
                     if ckpt_dir is not None else None)

        # delivery/accounting state (all of it crosses the checkpoint)
        self._client_of: dict[int, str] = {}     # live submission -> client
        self._inflight: dict[str, int] = {}      # client -> live count
        self._next_result = scheduler._ready.next_index
        self.completed: dict[int, SequenceTracks] = {}
        self._futures: dict[int, asyncio.Future] = {}
        self.sheds: list[tuple[str, str, float]] = []  # (client, reason, hint)

    # -------------------------------------------------------------- intake
    @property
    def pending(self) -> int:
        """Live (admitted, unfinished-or-undelivered) submissions."""
        return sum(self._inflight.values())

    def _bucket(self, client: str) -> Optional[TokenBucket]:
        if self._rate is None:
            return None
        if client not in self._buckets:
            self._buckets[client] = TokenBucket(self._rate, self._burst,
                                                clock=self._clock)
        return self._buckets[client]

    async def submit(self, name: str, det_boxes: np.ndarray,
                     det_mask: np.ndarray, *, client: str = "default",
                     det_class: Optional[np.ndarray] = None,
                     det_embed: Optional[np.ndarray] = None) -> int:
        """Admit one sequence or shed it with :class:`Overloaded`.

        Checks run cheapest-first: breaker state, the client's token
        bucket, then the queue bounds — a shed consumes no bucket token
        beyond the rate check itself and leaves no state behind."""
        if self.breaker.state == CircuitBreaker.OPEN:
            self._shed(client, "breaker_open",
                       max(self.breaker.retry_after(), self.retry_after_hint))
        bucket = self._bucket(client)
        if bucket is not None:
            wait = bucket.try_take()
            if wait > 0.0:
                self._shed(client, "rate", wait)
        if self.pending >= self.max_pending:
            self._shed(client, "queue", self.retry_after_hint)
        if self._inflight.get(client, 0) >= self.per_client_pending:
            self._shed(client, "client_queue", self.retry_after_hint)
        idx = self.sched.submit(name, det_boxes, det_mask,
                                det_class=det_class, det_embed=det_embed)
        self._client_of[idx] = client
        self._inflight[client] = self._inflight.get(client, 0) + 1
        # zero-frame sequences finalize inside submit(); release them (and
        # anything they unblocked) without waiting for a chunk dispatch.
        self._deliver(self.sched.pop_ready())
        return idx

    def _shed(self, client: str, reason: str, retry_after: float):
        self.sheds.append((client, reason, retry_after))
        raise Overloaded(reason, retry_after)

    # ------------------------------------------------------------- pumping
    @property
    def busy(self) -> bool:
        return self.sched.busy

    async def step(self) -> list[SequenceTracks]:
        """Dispatch one scheduler chunk through the breaker, deliver what
        finished, then checkpoint the boundary.

        Failure path: the exception is recorded with the breaker and the
        scheduler is rolled back to the latest committed checkpoint (a
        failed dispatch leaves host planning advanced past device state
        — rollback realigns them so the half-open probe retries the same
        chunk).  The original exception propagates.
        """
        if not self.breaker.allow():
            raise Overloaded("breaker_open",
                             max(self.breaker.retry_after(),
                                 self.retry_after_hint))
        try:
            results = self.sched.run_chunk()
        except Exception:
            self.breaker.record_failure()
            self._rollback()
            raise
        self.breaker.record_success()
        self._deliver(results)
        if self.ckpt is not None and \
                self.sched.chunks_run % self.ckpt_every == 0:
            self.checkpoint()
        return results

    async def drain(self, max_failures: Optional[int] = None
                    ) -> list[SequenceTracks]:
        """Step until the scheduler owes nothing, pacing around an open
        breaker.  ``max_failures`` bounds dispatch failures (then the
        last one re-raises); ``None`` retries forever."""
        out: list[SequenceTracks] = []
        failures = 0
        while self.busy:
            if not self.breaker.allow():
                await asyncio.sleep(min(self.breaker.retry_after(), 0.05))
                continue
            try:
                out.extend(await self.step())
            except Overloaded:
                continue
            except Exception:
                failures += 1
                if max_failures is not None and failures > max_failures:
                    raise
        if self.ckpt is not None:
            self.ckpt.wait()            # surface any async write failure
        return out

    async def result(self, index: int) -> SequenceTracks:
        """Await one submission's finished tracks (submission index from
        :meth:`submit`).  Already-delivered results resolve immediately —
        including after :meth:`resume`, where re-delivered duplicates
        land in ``completed`` before any future exists."""
        if index in self.completed:
            return self.completed[index]
        fut = self._futures.get(index)
        if fut is None:
            fut = self._futures[index] = \
                asyncio.get_running_loop().create_future()
        return await fut

    def _deliver(self, results: list[SequenceTracks]) -> None:
        """Hand finished sequences to their consumers — BEFORE the
        covering checkpoint commits (at-least-once, DESIGN.md §11).
        Tolerates re-delivery after a rollback or resume: futures may
        already be resolved, files already written (idempotent)."""
        for tracks in results:
            idx = self._next_result
            self._next_result += 1
            self.completed[idx] = tracks
            client = self._client_of.pop(idx, None)
            if client is not None:
                left = self._inflight.get(client, 0) - 1
                if left > 0:
                    self._inflight[client] = left
                else:
                    self._inflight.pop(client, None)
            fut = self._futures.get(idx)
            if fut is not None and not fut.done():
                fut.set_result(tracks)
            if self.on_result is not None:
                self.on_result(idx, tracks)

    # -------------------------------------------------- checkpoint/restore
    def checkpoint(self, wait: bool = False) -> int:
        """Snapshot the FULL service state at the current chunk boundary;
        returns the step number.  The write is async (double-buffered);
        any failure surfaces on the next call or :meth:`close` — never
        silently (repro.ckpt contract)."""
        if self.ckpt is None:
            raise ValueError("service was constructed without ckpt_dir")
        meta, arrays = self.sched.export_state()
        smeta = {
            "schema": 1,
            "sched": meta,
            "service": {
                "next_result": self._next_result,
                "client_of": {str(i): c
                              for i, c in self._client_of.items()},
            },
        }
        blob = np.frombuffer(json.dumps(smeta).encode(), np.uint8).copy()
        tree = dict(arrays)
        tree[SERVICE_META_KEY] = blob
        step = self.sched.chunks_run
        self.ckpt.save_async(step, tree)
        if wait:
            self.ckpt.wait()
        return step

    def _rollback(self) -> None:
        """Re-import the latest committed checkpoint after a dispatch
        failure, realigning host planning with device state.  Without a
        checkpoint directory (or before the first commit) this is a
        no-op: the failed chunk's planned frames are lost to this
        process, exactly the gap checkpoints exist to close."""
        if self.ckpt is None:
            return
        self.ckpt.wait()
        steps = committed_steps(self.ckpt.ckpt_dir)
        if not steps:
            return
        flat, _ = restore_flat(self.ckpt.ckpt_dir, step=steps[-1])
        smeta = json.loads(bytes(flat.pop(SERVICE_META_KEY).tobytes())
                           .decode())
        self.sched.import_state(smeta["sched"], flat)
        self._next_result = self.sched._ready.next_index

    @classmethod
    def resume(cls, scheduler, ckpt_dir: str, *, step: Optional[int] = None,
               **knobs) -> "TrackingService":
        """Rebuild a service from its latest (or ``step``-th) committed
        checkpoint.  ``scheduler`` must be freshly constructed with a
        semantically identical engine; the execution strategy may differ
        (the state contract is topology-neutral, DESIGN.md §11) — a
        same-strategy resume is bit-exact, a cross-strategy one exact in
        track identities and allclose in coordinates.  The scheduler's
        pre-resume contents are discarded by ``import_state``.  Accepts
        the same ``**knobs`` as the constructor (``ckpt_dir`` is implied).
        """
        flat, _ = restore_flat(ckpt_dir, step=step)
        if SERVICE_META_KEY not in flat:
            raise ValueError(
                f"checkpoint in {ckpt_dir!r} carries no service metadata "
                f"({SERVICE_META_KEY}) — it is a bare-scheduler or model "
                f"checkpoint, not a TrackingService snapshot")
        smeta = json.loads(bytes(flat.pop(SERVICE_META_KEY).tobytes())
                           .decode())
        if smeta.get("schema") != 1:
            raise ValueError(f"unsupported service checkpoint schema "
                             f"{smeta.get('schema')!r}")
        scheduler.import_state(smeta["sched"], flat)
        svc = cls(scheduler, ckpt_dir=ckpt_dir, **knobs)
        svc._next_result = int(smeta["service"]["next_result"])
        for i, client in smeta["service"]["client_of"].items():
            svc._client_of[int(i)] = client
            svc._inflight[client] = svc._inflight.get(client, 0) + 1
        return svc

    def close(self) -> None:
        """Flush the async checkpoint writer; raises any deferred write
        failure (the no-silent-loss contract)."""
        if self.ckpt is not None:
            self.ckpt.wait()
