"""Serving layer — online multiplexing of ragged workloads onto the engine.

The paper's throughput result assigns one worker per video file; real
serving traffic is an unbounded set of sequences with ragged lengths
(paper Table I spans 71–1000 frames).  :mod:`repro.serve.scheduler`
multiplexes that traffic onto the engine's fixed lane budget with exact
lane recycling (DESIGN.md §3); :mod:`repro.serve.service` puts the
production front-end around it — bounded async admission with explicit
backpressure, a circuit breaker over device dispatch, and crash-exact
checkpoint/restore (DESIGN.md §11).
"""
from .scheduler import StreamScheduler, lane_ladder  # noqa: F401
from .service import (CircuitBreaker, Overloaded,  # noqa: F401
                      TokenBucket, TrackingService)
