"""Online multi-stream scheduler — ragged-length lane recycling.

The paper parallelizes throughput by giving each OpenMP worker one video
file (§VI); its TPU analogue (``SortEngine.run``) scans a *fixed* batch of
equal-length streams.  Real serving traffic is neither fixed nor
equal-length: sequences arrive over time with lengths spanning an order of
magnitude (paper Table I: 71–1000 frames), so a pad-to-max batch wastes
most of its lane-steps on padding and a re-batch-per-departure recompiles
constantly.

This scheduler multiplexes an unbounded queue of ragged sequences onto a
fixed budget of ``num_lanes`` engine lanes (DESIGN.md §3):

* **Admission** is FIFO: the moment a lane's sequence ends, the lane is
  recycled — masked re-init (``core.sort.reset_ragged``) plus the new
  sequence's first frame execute in the *same* fused step.
* **Ragged stepping**: every step runs ``SortEngine.step_ragged`` with a
  per-lane ``active`` mask, so lanes between sequences are exact no-ops
  inside the single dispatch — membership churns every frame with no
  re-dispatch and no recompilation.
* **Chunked execution**: the host plans ``chunk`` steps at a time (the
  admission schedule is data-independent, so it can be planned ahead) and
  runs them as one jitted ``lax.scan`` — one host round-trip per chunk,
  not per frame.
* **Drain**: finished sequences are emitted **in submission order** via
  :class:`repro.data.stream.ReorderBuffer`; each carries its dense track
  stream (:class:`repro.data.stream.SequenceTracks`), bit-identical to a
  solo run of that sequence (the lane-recycling invariant, locked down by
  ``tests/test_scheduler.py``).
* **Device sharding** (DESIGN.md §7): pass ``mesh=`` (a 1-D ``("lanes",)``
  mesh from :func:`repro.sharding.lane_mesh`) and the lane axis is split
  contiguously over the mesh's devices — each device scans its own lane
  shard with the same single fused dispatch per step, zero collectives,
  and bit-identical outputs (``tests/test_device_sharding.py``).  Host-
  side planning is unchanged; chunk operands are placed with
  ``NamedSharding`` so the jitted scan never inserts a resharding copy.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slots, sort as sort_mod
from repro.core.sort import SortEngine
from repro.data.stream import ReorderBuffer, SequenceTracks


@dataclasses.dataclass
class _Seq:
    """One submitted sequence and its in-flight output buffers."""

    index: int
    name: str
    det_boxes: np.ndarray          # [F, D, 4] padded to the scheduler's D
    det_mask: np.ndarray           # [F, D]
    boxes: list = dataclasses.field(default_factory=list)
    uid: list = dataclasses.field(default_factory=list)
    emit: list = dataclasses.field(default_factory=list)

    @property
    def length(self) -> int:
        return self.det_boxes.shape[0]


class StreamScheduler:
    """Multiplex ragged sequences onto ``num_lanes`` recycled engine lanes.

    Works with both engine paths: ``use_kernels=True`` keeps a resident
    :class:`~repro.core.LaneSortState` and masks inside the fused kernel;
    ``use_kernels=False`` masks the per-phase engine step.  Both
    association modes (``SortConfig.assoc``, DESIGN.md §6) serve through
    the same chunked scan — the fused-Hungarian JV stage sees the masked
    per-lane detections, so inactive lanes stay exact no-ops.  Either way
    a sequence's emitted tracks are bit-identical to running it alone.

    Usage::

        sched = StreamScheduler(engine, num_lanes=4)
        for name, db, dm in sequences:
            sched.submit(name, db, dm)
        for tracks in sched.run():      # submission order
            ...

    ``submit`` may be called again after ``run`` returns; lane state
    persists but every admission starts from a masked re-init, so earlier
    traffic cannot leak into later sequences.
    """

    def __init__(self, engine: SortEngine, num_lanes: int,
                 max_dets: Optional[int] = None, chunk: int = 32,
                 mesh=None):
        if num_lanes < 1:
            raise ValueError(f"num_lanes must be >= 1, got {num_lanes}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.engine = engine
        self.num_lanes = num_lanes
        self.max_dets = max_dets or engine.config.max_detections
        self.chunk = chunk
        self.mesh = mesh

        self._pending: collections.deque[_Seq] = collections.deque()
        self._occupant: list[Optional[_Seq]] = [None] * num_lanes
        self._cursor = [0] * num_lanes
        self._ready = ReorderBuffer()
        self._num_submitted = 0

        # serving counters (benchmarks/ragged.py reads these)
        self.frames_processed = 0      # real sequence frames stepped
        # lanes x steps that carried any planned work: steps of a chunk
        # whose `active` mask is all-False (the tail of a draining final
        # chunk) are excluded, so `utilization` measures lane occupancy of
        # working steps rather than being diluted by drain padding.
        self.lane_steps = 0
        self.chunks_run = 0
        self.admissions: list[tuple[int, int]] = []  # (seq index, step)

        def chunk_fn(state, det, dm, active, reset):
            def body(st, inp):
                d, m, a, r = inp
                # recycle + admitted sequence's first frame: same fused step
                st = sort_mod.reset_ragged(st, r)
                return self.engine.step_ragged(st, d, m, a)
            return jax.lax.scan(body, state, (det, dm, active, reset))

        if mesh is None:
            self._sharding = None
            self._state = engine.init_ragged(num_lanes)
            self._chunk_fn = jax.jit(chunk_fn)
        else:
            # lanes -> mesh (DESIGN.md §7): validate the lane budget splits
            # evenly, shard the resident state, and wrap the identical
            # chunk scan in shard_map — planning above stays host-side and
            # device-count-agnostic.
            from repro.sharding.lanes import LaneSharding
            self._sharding = LaneSharding(engine, mesh, num_lanes)
            self._state = self._sharding.init()
            self._chunk_fn = jax.jit(self._sharding.shard_chunk(chunk_fn))

    # --------------------------------------------------------------- intake
    def submit(self, name: str, det_boxes: np.ndarray,
               det_mask: np.ndarray) -> int:
        """Queue one sequence (``det_boxes [F, D_i, 4]``, ``det_mask
        [F, D_i]``); returns its submission index.  ``D_i`` must not exceed
        the scheduler's detection budget."""
        det_boxes = np.asarray(det_boxes, np.float32)
        det_mask = np.asarray(det_mask, bool)
        f, d_i = det_mask.shape
        if d_i > self.max_dets:
            raise ValueError(
                f"sequence {name!r} has {d_i} detection slots, scheduler "
                f"budget is {self.max_dets}")
        if d_i < self.max_dets:
            pad = self.max_dets - d_i
            det_boxes = np.pad(det_boxes, ((0, 0), (0, pad), (0, 0)))
            det_mask = np.pad(det_mask, ((0, 0), (0, pad)))
        seq = _Seq(self._num_submitted, name, det_boxes, det_mask)
        self._num_submitted += 1
        if f == 0:  # nothing to step; complete immediately (still in order)
            self._finalize(seq)
        else:
            self._pending.append(seq)
        return seq.index

    @property
    def busy(self) -> bool:
        """True while the scheduler still owes the caller anything: queued
        or in-flight sequences, *or* finished results buffered for
        in-order release.  (The buffered term matters: a zero-frame
        sequence submitted while idle finalizes straight into the reorder
        buffer without ever occupying a lane — ``busy`` ignoring it left
        that result stranded, since drain loops stopped before anything
        popped it.)"""
        return self._has_step_work or len(self._ready) > 0

    @property
    def _has_step_work(self) -> bool:
        """Anything left that requires dispatching a chunk."""
        return bool(self._pending) or any(
            s is not None for s in self._occupant)

    @property
    def utilization(self) -> float:
        """Fraction of dispatched working lane-steps that carried a real
        frame (``frames_processed / lane_steps``).  Fully-idle tail steps
        of a draining chunk are excluded from the denominator — they hold
        no lanes hostage, they only pad the final ``lax.scan``."""
        return self.frames_processed / max(self.lane_steps, 1)

    # ------------------------------------------------------------- planning
    def _plan_chunk(self):
        """Plan the next ``chunk`` steps of the lane schedule on the host.

        Admission is data-independent (it depends only on queue order and
        sequence lengths), so the whole chunk — including mid-chunk
        recycling — is planned before anything is dispatched."""
        c, l, d = self.chunk, self.num_lanes, self.max_dets
        det = np.zeros((c, l, d, 4), np.float32)
        dm = np.zeros((c, l, d), bool)
        active = np.zeros((c, l), bool)
        reset = np.zeros((c, l), bool)
        mapping = []                                  # (t, lane, seq, frame)
        for t in range(c):
            for lane in range(l):
                if self._occupant[lane] is None and self._pending:
                    self._occupant[lane] = self._pending.popleft()
                    self._cursor[lane] = 0
                    reset[t, lane] = True             # recycle in this step
                    self.admissions.append(
                        (self._occupant[lane].index,
                         self.chunks_run * self.chunk + t))
                seq = self._occupant[lane]
                if seq is None:
                    continue
                k = self._cursor[lane]
                det[t, lane] = seq.det_boxes[k]
                dm[t, lane] = seq.det_mask[k]
                active[t, lane] = True
                mapping.append((t, lane, seq, k))
                self._cursor[lane] = k + 1
                if k + 1 == seq.length:               # lane free next step
                    self._occupant[lane] = None
        return det, dm, active, reset, mapping

    # ------------------------------------------------------------ execution
    def _run_chunk(self) -> list[SequenceTracks]:
        if not self._has_step_work:
            # nothing to dispatch — only buffered completions to release
            return self._ready.pop_ready()
        det, dm, active, reset, mapping = self._plan_chunk()
        if self._sharding is not None:
            operands = self._sharding.place(det, dm, active, reset)
        else:
            operands = (jnp.asarray(det), jnp.asarray(dm),
                        jnp.asarray(active), jnp.asarray(reset))
        self._state, outs = self._chunk_fn(self._state, *operands)
        self._check_uid_headroom()
        boxes = np.asarray(outs.boxes)                # [C, L, T, 4]
        uid = np.asarray(outs.uid)
        emit = np.asarray(outs.emit)
        finished = []
        for t, lane, seq, k in mapping:
            # copies, so buffering a row doesn't pin the whole chunk array
            # until a long-running neighbour sequence finalizes
            seq.boxes.append(boxes[t, lane].copy())
            seq.uid.append(uid[t, lane].copy())
            seq.emit.append(emit[t, lane].copy())
            if k + 1 == seq.length:
                finished.append(seq)
        self.frames_processed += len(mapping)
        # denominator from the planned schedule, not the raw chunk size:
        # fully-idle tail steps of a draining chunk carry no lanes' work
        self.lane_steps += int(active.any(axis=1).sum()) * self.num_lanes
        self.chunks_run += 1
        for seq in finished:
            self._finalize(seq)
        return self._ready.pop_ready()

    def _finalize(self, seq: _Seq) -> None:
        t = self.engine.config.max_trackers
        self._ready.put(seq.index, SequenceTracks(
            name=seq.name,
            boxes=(np.stack(seq.boxes) if seq.boxes
                   else np.zeros((0, t, 4), np.float32)),
            uid=(np.stack(seq.uid) if seq.uid
                 else np.zeros((0, t), np.int32)),
            emit=(np.stack(seq.emit) if seq.emit
                  else np.zeros((0, t), bool)),
        ))

    def _check_uid_headroom(self) -> None:
        """Guard the per-lane int32 uid counter (``SlotPool.next_uid``).

        ``reset_ragged`` resets the counter to ``uid_start`` on every lane
        recycle, so under normal serving the counter is bounded by one
        sequence's birth count.  A single monster sequence can still run
        it toward int32 overflow; rather than silently wrapping onto uids
        that may *still be alive*, fail loudly with the remediation.  The
        check fetches the ``[L]`` int32 counter row each chunk (a tiny
        cross-device gather in mesh mode) — negligible next to the chunk's
        own output transfer, and the chunk boundary is already a host
        sync point.
        """
        next_uid = np.asarray(self._state.pool.next_uid)
        if next_uid.size and int(next_uid.max()) > slots.UID_LIMIT:
            lane = int(next_uid.argmax())
            raise RuntimeError(
                f"track uid counter on lane {lane} exceeded "
                f"slots.UID_LIMIT ({slots.UID_LIMIT}): a single sequence "
                f"allocated ~2**31 track ids.  uids are int32 and only "
                f"reset when the lane is recycled (reset_ragged); split "
                f"the sequence or re-admit it to reset its uid namespace.")

    def pop_ready(self) -> list[SequenceTracks]:
        """Release every finished sequence whose turn has come (submission
        order), **without dispatching anything** — the drain path for
        results that finalized off the chunk path (e.g. zero-frame
        sequences completed at ``submit`` time)."""
        return self._ready.pop_ready()

    def drain(self) -> list[SequenceTracks]:
        """Run chunks until no step work remains, then release everything
        buffered; returns all newly finished sequences in submission
        order.  Never dispatches an empty chunk."""
        results = []
        while self._has_step_work:
            results.extend(self._run_chunk())
        results.extend(self.pop_ready())
        return results

    def run(self) -> list[SequenceTracks]:
        """Process every submitted sequence to completion (drain), returning
        their track streams **in submission order**."""
        return self.drain()
