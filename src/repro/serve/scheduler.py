"""Online multi-stream scheduler — ragged-length lane recycling.

The paper parallelizes throughput by giving each OpenMP worker one video
file (§VI); its TPU analogue (``SortEngine.run``) scans a *fixed* batch of
equal-length streams.  Real serving traffic is neither fixed nor
equal-length: sequences arrive over time with lengths spanning an order of
magnitude (paper Table I: 71–1000 frames), so a pad-to-max batch wastes
most of its lane-steps on padding and a re-batch-per-departure recompiles
constantly.

This scheduler multiplexes an unbounded queue of ragged sequences onto a
fixed budget of ``num_lanes`` engine lanes (DESIGN.md §3):

* **Admission** is FIFO: the moment a lane's sequence ends, the lane is
  recycled — masked re-init (``core.sort.reset_ragged``) plus the new
  sequence's first frame execute in the *same* fused step.
* **Ragged stepping**: every step runs ``SortEngine.step_ragged`` with a
  per-lane ``active`` mask, so lanes between sequences are exact no-ops
  inside the single dispatch — membership churns every frame with no
  re-dispatch and no recompilation.
* **Chunked execution**: the host plans ``chunk`` steps at a time (the
  admission schedule is data-independent, so it can be planned ahead) and
  runs them as one jitted ``lax.scan`` — one host round-trip per chunk,
  not per frame.
* **Drain**: finished sequences are emitted **in submission order** via
  :class:`repro.data.stream.ReorderBuffer`; each carries its dense track
  stream (:class:`repro.data.stream.SequenceTracks`), bit-identical to a
  solo run of that sequence (the lane-recycling invariant, locked down by
  ``tests/test_scheduler.py``).
* **Device sharding** (DESIGN.md §7): pass ``mesh=`` (a 1-D ``("lanes",)``
  mesh from :func:`repro.sharding.lane_mesh`) and the lane axis is split
  contiguously over the mesh's devices — each device scans its own lane
  shard with the same single fused dispatch per step, zero collectives,
  and bit-identical outputs (``tests/test_device_sharding.py``).  Host-
  side planning is unchanged; chunk operands are placed with
  ``NamedSharding`` so the jitted scan never inserts a resharding copy.
* **Elastic lane budgets** (DESIGN.md §8): pass ``min_lanes``/``max_lanes``
  and the budget resizes itself between chunks over a pre-compiled ladder
  of power-of-two widths — grow is immediate (appended lanes are a masked
  re-init), shrink waits for the evacuating lanes to drain, and migrated
  lanes (including lanes mid-sequence) survive the move bit for bit, so
  an elastic run's per-sequence outputs equal a fixed ``max_lanes`` run
  (``tests/test_autoscale.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slots
from repro.core.sort import SortEngine, lane_state_of, sort_state_of
from repro.data.stream import ReorderBuffer, SequenceTracks


def lane_ladder(min_lanes: int, max_lanes: int) -> tuple[int, ...]:
    """The pre-compiled width ladder (DESIGN.md §8): power-of-two
    multiples of ``min_lanes`` up to ``max_lanes``.

    Every resize lands on a ladder width, so the chunk scan compiles at
    most once per width and never again — ``max_lanes`` must therefore be
    ``min_lanes * 2**k`` exactly (a width off the ladder would force a
    fresh compile at resize time, the thing the ladder exists to avoid).
    """
    if min_lanes < 1:
        raise ValueError(f"min_lanes must be >= 1, got {min_lanes}")
    if max_lanes < min_lanes:
        raise ValueError(f"max_lanes={max_lanes} must be >= "
                         f"min_lanes={min_lanes}")
    widths = [min_lanes]
    while widths[-1] < max_lanes:
        widths.append(widths[-1] * 2)
    if widths[-1] != max_lanes:
        raise ValueError(
            f"max_lanes={max_lanes} must be min_lanes * 2**k "
            f"(min_lanes={min_lanes} reaches {widths[-2]} or {widths[-1]})")
    return tuple(widths)


@dataclasses.dataclass
class _Seq:
    """One submitted sequence and its in-flight output buffers."""

    index: int
    name: str
    det_boxes: np.ndarray          # [F, D, 4] padded to the scheduler's D
    det_mask: np.ndarray           # [F, D]
    det_class: Optional[np.ndarray] = None   # [F, D] int32 (multi-class)
    det_embed: Optional[np.ndarray] = None   # [F, D, E] (embed costs)
    boxes: list = dataclasses.field(default_factory=list)
    uid: list = dataclasses.field(default_factory=list)
    emit: list = dataclasses.field(default_factory=list)
    cls: list = dataclasses.field(default_factory=list)

    @property
    def length(self) -> int:
        return self.det_boxes.shape[0]


class StreamScheduler:
    """Multiplex ragged sequences onto ``num_lanes`` recycled engine lanes.

    Works with both engine paths: ``use_kernels=True`` keeps a resident
    :class:`~repro.core.LaneSortState` and masks inside the fused kernel;
    ``use_kernels=False`` masks the per-phase engine step.  Both
    association modes (``SortConfig.assoc``, DESIGN.md §6) serve through
    the same chunked scan — the fused-Hungarian JV stage sees the masked
    per-lane detections, so inactive lanes stay exact no-ops.  Either way
    a sequence's emitted tracks are bit-identical to running it alone.

    Usage::

        sched = StreamScheduler(engine, num_lanes=4)
        for name, db, dm in sequences:
            sched.submit(name, db, dm)
        for tracks in sched.run():      # submission order
            ...

    ``submit`` may be called again after ``run`` returns; lane state
    persists but every admission starts from a masked re-init, so earlier
    traffic cannot leak into later sequences.

    **Elastic mode** (DESIGN.md §8): pass ``min_lanes``/``max_lanes`` and
    the budget autoscales over the pre-compiled ladder
    (:func:`lane_ladder`) between chunks.  Resize policy knobs:

    * ``min_lanes`` / ``max_lanes`` — the ladder bounds; ``max_lanes``
      must be ``min_lanes * 2**k``.  ``num_lanes`` (optional here) picks
      the starting width, default ``min_lanes``.
    * **grow** is demand-driven and immediate: when occupied lanes plus
      queue depth exceed the current width, the budget steps up to the
      smallest ladder width covering demand before the next chunk is
      planned (appended lanes are a masked re-init).
    * **shrink** is utilization-driven and patient: when demand fits a
      smaller ladder width for ``shrink_patience`` consecutive chunk
      boundaries (hysteresis against bursty arrivals), admissions to the
      evacuating lanes stop, and the budget drops only once those lanes
      have drained — no live sequence is ever moved or cancelled.
    * ``precompile`` — compile every ladder width's chunk program at
      construction (on throwaway all-inactive chunks), so a mid-burst
      resize never pays compile latency.  Repeated resizes never retrace
      a compiled width either way (``trace_log`` records one entry per
      chunk-shape trace; ``tests/test_autoscale.py`` locks this).
    * :meth:`request_width` — pin a target width (tests, external
      autoscalers); it overrides the demand policy until released with
      ``request_width(None)``.  A pinned shrink still waits for the
      evacuating lanes to drain.
    """

    def __init__(self, engine: SortEngine, num_lanes: Optional[int] = None,
                 max_dets: Optional[int] = None, chunk: int = 32,
                 mesh=None, *, min_lanes: Optional[int] = None,
                 max_lanes: Optional[int] = None, shrink_patience: int = 2,
                 precompile: bool = True):
        self.elastic = min_lanes is not None or max_lanes is not None
        if self.elastic:
            if min_lanes is None or max_lanes is None:
                raise ValueError(
                    "elastic mode needs both min_lanes and max_lanes")
            self.ladder = lane_ladder(min_lanes, max_lanes)
            num_lanes = self.ladder[0] if num_lanes is None else num_lanes
            if num_lanes not in self.ladder:
                raise ValueError(
                    f"num_lanes={num_lanes} must be a ladder width "
                    f"{self.ladder}")
            if shrink_patience < 1:
                raise ValueError(f"shrink_patience must be >= 1, got "
                                 f"{shrink_patience}")
        else:
            if num_lanes is None:
                raise ValueError("num_lanes is required for a fixed budget "
                                 "(pass min_lanes/max_lanes for elastic)")
            self.ladder = (num_lanes,)
        if num_lanes < 1:
            raise ValueError(f"num_lanes must be >= 1, got {num_lanes}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.engine = engine
        self.num_lanes = num_lanes      # CURRENT width (mutates in elastic)
        self.max_dets = max_dets or engine.config.max_detections
        self.chunk = chunk
        self.mesh = mesh
        self.shrink_patience = shrink_patience

        # class/embed operand threading (DESIGN.md §10): required exactly
        # when the engine's cost/partition config consumes them, so the
        # single-class IoU scheduler plans and dispatches byte-identical
        # chunks to the pre-multiclass code.
        self._need_class = engine.config.num_classes > 1
        self._need_embed = engine.config.cost.uses_embed
        self._embed_dim = engine.config.cost.embed_dim
        self._extra_ndims = ((3,) if self._need_class else ()) + \
                            ((4,) if self._need_embed else ())

        self._pending: collections.deque[_Seq] = collections.deque()
        self._occupant: list[Optional[_Seq]] = [None] * num_lanes
        self._cursor = [0] * num_lanes
        self._ready = ReorderBuffer()
        self._num_submitted = 0
        self._shrink_target: Optional[int] = None   # evacuating toward this
        self._shrink_votes = 0                      # hysteresis counter
        self._forced_width: Optional[int] = None    # request_width override

        # serving counters (benchmarks/ragged.py reads these)
        self.frames_processed = 0      # real sequence frames stepped
        # lanes x steps that carried any planned work: steps of a chunk
        # whose `active` mask is all-False (the tail of a draining final
        # chunk) are excluded, so `utilization` measures lane occupancy of
        # working steps rather than being diluted by drain padding.  The
        # lane factor is the width ACTIVE at each chunk, not the
        # construction width (elastic mode resizes between chunks).
        self.lane_steps = 0
        self.chunks_run = 0
        self.admissions: list[tuple[int, int]] = []  # (seq index, step)
        self.resizes: list[tuple[int, int, int]] = []  # (chunk, old, new)
        # one entry (the traced lane width; per-shard width in mesh mode)
        # per chunk-program trace — the recompilation probe: repeated
        # grow/shrink cycles must never retrace a compiled ladder width.
        self.trace_log: list[int] = []

        need_class, need_embed = self._need_class, self._need_embed

        def chunk_fn(state, det, dm, active, reset, *extras):
            self.trace_log.append(det.shape[1])    # runs at trace time only
            # F serving steps in one call: a per-frame jitted scan, or —
            # with SortConfig.chunk_kernel — ONE chunk-resident pallas_call
            # (DESIGN.md §9).  Everything above this line (planning,
            # accounting, trace_log, the elastic ladder, sharding) is
            # identical under both dispatch modes: the granularity change
            # lives entirely inside the engine call.
            it = iter(extras)
            dc = next(it) if need_class else None
            de = next(it) if need_embed else None
            return self.engine.run_chunk_ragged(state, det, dm, active,
                                                reset, det_class=dc,
                                                det_embed=de)

        if mesh is None:
            self._sharding = None
            self._shardings = None
            self._state = engine.init_ragged(num_lanes)
            self._chunk_fn = jax.jit(chunk_fn)
        else:
            # lanes -> mesh (DESIGN.md §7): validate the lane budget splits
            # evenly (every ladder width, so no resize can fail later),
            # shard the resident state, and wrap the identical chunk scan
            # in shard_map — planning above stays host-side and
            # device-count-agnostic.  One jitted chunk program serves all
            # widths: the PartitionSpecs depend on state structure, not
            # lane count, so each width is just one more shape in its
            # cache.
            from repro.sharding.lanes import LaneSharding, shard_count
            n = shard_count(mesh)
            for w in self.ladder:
                if w % n != 0:
                    raise ValueError(
                        f"ladder width {w} (of {self.ladder}) must divide "
                        f"evenly over the {n}-device lane mesh")
            self._shardings: dict[int, LaneSharding] = {}
            self._sharding = self._sharding_for(num_lanes)
            self._state = self._sharding.init()
            self._chunk_fn = jax.jit(self._sharding.shard_chunk(
                chunk_fn, extra_operand_ndims=self._extra_ndims))
        if self.elastic and precompile:
            self._precompile_ladder()

    # --------------------------------------------------------------- intake
    def submit(self, name: str, det_boxes: np.ndarray,
               det_mask: np.ndarray, det_class: Optional[np.ndarray] = None,
               det_embed: Optional[np.ndarray] = None) -> int:
        """Queue one sequence (``det_boxes [F, D_i, 4]``, ``det_mask
        [F, D_i]``); returns its submission index.  ``D_i`` must not exceed
        the scheduler's detection budget.  ``det_class [F, D_i]`` int /
        ``det_embed [F, D_i, E]`` are required exactly when the engine's
        config partitions classes / composes an embedding cost
        (DESIGN.md §10), and ignored otherwise."""
        det_boxes = np.asarray(det_boxes, np.float32)
        det_mask = np.asarray(det_mask, bool)
        f, d_i = det_mask.shape
        if d_i > self.max_dets:
            raise ValueError(
                f"sequence {name!r} has {d_i} detection slots, scheduler "
                f"budget is {self.max_dets}")
        if self._need_class and det_class is None:
            raise ValueError(
                f"sequence {name!r}: det_class is required when "
                f"num_classes={self.engine.config.num_classes} > 1")
        if self._need_embed and det_embed is None:
            raise ValueError(
                f"sequence {name!r}: det_embed is required when the cost "
                f"has an embedding term ({self.engine.config.cost})")
        dc = (np.asarray(det_class, np.int32) if self._need_class else None)
        de = (np.asarray(det_embed, np.float32) if self._need_embed else None)
        if d_i < self.max_dets:
            pad = self.max_dets - d_i
            det_boxes = np.pad(det_boxes, ((0, 0), (0, pad), (0, 0)))
            det_mask = np.pad(det_mask, ((0, 0), (0, pad)))
            if dc is not None:
                dc = np.pad(dc, ((0, 0), (0, pad)))
            if de is not None:
                de = np.pad(de, ((0, 0), (0, pad), (0, 0)))
        seq = _Seq(self._num_submitted, name, det_boxes, det_mask,
                   det_class=dc, det_embed=de)
        self._num_submitted += 1
        if f == 0:  # nothing to step; complete immediately (still in order)
            self._finalize(seq)
        else:
            self._pending.append(seq)
        return seq.index

    @property
    def busy(self) -> bool:
        """True while the scheduler still owes the caller anything: queued
        or in-flight sequences, *or* finished results buffered for
        in-order release.  (The buffered term matters: a zero-frame
        sequence submitted while idle finalizes straight into the reorder
        buffer without ever occupying a lane — ``busy`` ignoring it left
        that result stranded, since drain loops stopped before anything
        popped it.)"""
        return self._has_step_work or len(self._ready) > 0

    @property
    def _has_step_work(self) -> bool:
        """Anything left that requires dispatching a chunk."""
        return bool(self._pending) or any(
            s is not None for s in self._occupant)

    @property
    def utilization(self) -> float:
        """Fraction of dispatched working lane-steps that carried a real
        frame (``frames_processed / lane_steps``).  Fully-idle tail steps
        of a draining chunk are excluded from the denominator — they hold
        no lanes hostage, they only pad the final ``lax.scan``."""
        return self.frames_processed / max(self.lane_steps, 1)

    # ------------------------------------------------------------- elastic
    def _sharding_for(self, width: int):
        """The (cached) :class:`LaneSharding` for one ladder width."""
        from repro.sharding.lanes import LaneSharding
        if width not in self._shardings:
            self._shardings[width] = LaneSharding(self.engine, self.mesh,
                                                  width)
        return self._shardings[width]

    def _precompile_ladder(self) -> None:
        """Compile every ladder width's chunk program up front.

        Each width is traced on a throwaway freshly-init state with
        all-inactive operands — an inactive step is an exact no-op
        (DESIGN.md §3.2), so warm-up never touches serving state, and the
        operands carry exactly the dtypes/shardings real chunks use, so
        the first real chunk at any width is a cache hit.
        """
        c, d = self.chunk, self.max_dets
        for w in self.ladder:
            det = np.zeros((c, w, d, 4), np.float32)
            dm = np.zeros((c, w, d), bool)
            idle = np.zeros((c, w), bool)
            extras = self._zero_extras(c, w, d)
            if self._sharding is not None:
                sh = self._sharding_for(w)
                state = self._state if w == self.num_lanes else sh.init()
                operands = sh.place(det, dm, idle, idle, *extras)
            else:
                state = (self._state if w == self.num_lanes
                         else self.engine.init_ragged(w))
                operands = tuple(jnp.asarray(a)
                                 for a in (det, dm, idle, idle) + extras)
            self._chunk_fn(state, *operands)

    def _zero_extras(self, c: int, l: int, d: int) -> tuple:
        """All-zero class/embed chunk operands in dispatch order (class
        first), matching ``_extra_ndims``."""
        extras = ()
        if self._need_class:
            extras += (np.zeros((c, l, d), np.int32),)
        if self._need_embed:
            extras += (np.zeros((c, l, d, self._embed_dim), np.float32),)
        return extras

    def request_width(self, width: Optional[int]) -> None:
        """Pin the budget to ``width`` (a ladder width), overriding the
        demand policy until released with ``request_width(None)`` or
        superseded by a new pin: grow applies before the next chunk;
        shrink engages the drain protocol immediately (no hysteresis) but
        still waits for the evacuating lanes to empty — queued sequences
        re-queue into the surviving lanes, FIFO order intact.  Tests and
        external autoscalers use this; normal serving relies on the
        built-in policy."""
        if not self.elastic:
            raise ValueError("request_width needs an elastic scheduler "
                             "(min_lanes/max_lanes)")
        if width is not None and width not in self.ladder:
            raise ValueError(f"width {width} not on the ladder {self.ladder}")
        self._forced_width = width

    def _target_width(self) -> int:
        """Smallest ladder width covering current demand (occupied lanes
        plus queue depth) — the width at which the next chunk would run at
        the highest lane utilization without queueing admissible work."""
        occupied = sum(o is not None for o in self._occupant)
        demand = occupied + len(self._pending)
        for w in self.ladder:
            if w >= demand:
                return w
        return self.ladder[-1]

    def _maybe_resize(self) -> None:
        """Resize policy, run once per chunk boundary (before planning).

        Grow is immediate; shrink requires ``shrink_patience`` consecutive
        under-demand boundaries, then marks lanes ``>= target`` as
        evacuating (no further admissions) and applies only once they have
        all drained — so the budget never drops while a live sequence
        occupies a doomed lane, and uids never alias (recycling semantics
        are untouched)."""
        if not self.elastic:
            return
        forced = self._forced_width
        target = forced if forced is not None else self._target_width()
        if target > self.num_lanes:
            self._shrink_target = None           # growth cancels evacuation
            self._shrink_votes = 0
            self._apply_resize(target)
        elif target < self.num_lanes:
            self._shrink_votes = (self.shrink_patience if forced is not None
                                  else self._shrink_votes + 1)
            if self._shrink_votes >= self.shrink_patience:
                self._shrink_target = target
        else:
            self._shrink_votes = 0
            self._shrink_target = None
        if self._shrink_target is not None and all(
                o is None for o in self._occupant[self._shrink_target:]):
            self._apply_resize(self._shrink_target)
            self._shrink_target = None
            self._shrink_votes = 0

    def _apply_resize(self, new_width: int) -> None:
        """Migrate the resident state to ``new_width`` lanes at a chunk
        boundary.  Kept lanes (including lanes mid-sequence) move bit for
        bit; appended lanes are a masked re-init; in mesh mode the
        migrated state is re-placed with the new width's ``NamedSharding``
        here, so the next chunk starts from committed shardings."""
        old = self.num_lanes
        if new_width == old:
            return
        if self._sharding is not None:
            new_sharding = self._sharding_for(new_width)
            self._state = self._sharding.migrate(self._state, new_sharding)
            self._sharding = new_sharding
        else:
            self._state = self.engine.resize_ragged(self._state, old,
                                                    new_width)
        if new_width > old:
            self._occupant += [None] * (new_width - old)
            self._cursor += [0] * (new_width - old)
        else:
            assert all(o is None for o in self._occupant[new_width:]), \
                "shrink applied before the evacuating lanes drained"
            del self._occupant[new_width:]
            del self._cursor[new_width:]
        self.num_lanes = new_width
        self.resizes.append((self.chunks_run, old, new_width))

    # ------------------------------------------------------------- planning
    def _plan_chunk(self):
        """Plan the next ``chunk`` steps of the lane schedule on the host.

        Admission is data-independent (it depends only on queue order and
        sequence lengths), so the whole chunk — including mid-chunk
        recycling — is planned before anything is dispatched.  While a
        shrink is evacuating, lanes at or beyond the target width take no
        new admissions (their occupants run to completion); queued
        sequences keep admitting FIFO into the surviving lanes."""
        c, l, d = self.chunk, self.num_lanes, self.max_dets
        admit_limit = (l if self._shrink_target is None
                       else self._shrink_target)
        det = np.zeros((c, l, d, 4), np.float32)
        dm = np.zeros((c, l, d), bool)
        active = np.zeros((c, l), bool)
        reset = np.zeros((c, l), bool)
        extras = self._zero_extras(c, l, d)
        it = iter(extras)
        dc = next(it) if self._need_class else None
        de = next(it) if self._need_embed else None
        mapping = []                                  # (t, lane, seq, frame)
        for t in range(c):
            for lane in range(l):
                if self._occupant[lane] is None and self._pending \
                        and lane < admit_limit:
                    self._occupant[lane] = self._pending.popleft()
                    self._cursor[lane] = 0
                    reset[t, lane] = True             # recycle in this step
                    self.admissions.append(
                        (self._occupant[lane].index,
                         self.chunks_run * self.chunk + t))
                seq = self._occupant[lane]
                if seq is None:
                    continue
                k = self._cursor[lane]
                det[t, lane] = seq.det_boxes[k]
                dm[t, lane] = seq.det_mask[k]
                if dc is not None:
                    dc[t, lane] = seq.det_class[k]
                if de is not None:
                    de[t, lane] = seq.det_embed[k]
                active[t, lane] = True
                mapping.append((t, lane, seq, k))
                self._cursor[lane] = k + 1
                if k + 1 == seq.length:               # lane free next step
                    self._occupant[lane] = None
        return det, dm, active, reset, extras, mapping

    # ------------------------------------------------------------ execution
    def _run_chunk(self) -> list[SequenceTracks]:
        if not self._has_step_work:
            # nothing to dispatch — only buffered completions to release
            return self._ready.pop_ready()
        self._maybe_resize()
        det, dm, active, reset, extras, mapping = self._plan_chunk()
        if self._sharding is not None:
            operands = self._sharding.place(det, dm, active, reset, *extras)
        else:
            operands = tuple(jnp.asarray(a)
                             for a in (det, dm, active, reset) + extras)
        self._state, outs = self._chunk_fn(self._state, *operands)
        self._check_uid_headroom()
        boxes = np.asarray(outs.boxes)                # [C, L, T, 4]
        uid = np.asarray(outs.uid)
        emit = np.asarray(outs.emit)
        cls = np.asarray(outs.cls) if self._need_class else None
        finished = []
        for t, lane, seq, k in mapping:
            # copies, so buffering a row doesn't pin the whole chunk array
            # until a long-running neighbour sequence finalizes
            seq.boxes.append(boxes[t, lane].copy())
            seq.uid.append(uid[t, lane].copy())
            seq.emit.append(emit[t, lane].copy())
            if cls is not None:
                seq.cls.append(cls[t, lane].copy())
            if k + 1 == seq.length:
                finished.append(seq)
        self.frames_processed += len(mapping)
        # denominator from the planned schedule, not the raw chunk size:
        # fully-idle tail steps of a draining chunk carry no lanes' work
        self.lane_steps += int(active.any(axis=1).sum()) * self.num_lanes
        self.chunks_run += 1
        for seq in finished:
            self._finalize(seq)
        return self._ready.pop_ready()

    def _finalize(self, seq: _Seq) -> None:
        t = self.engine.config.max_trackers
        self._ready.put(seq.index, SequenceTracks(
            name=seq.name,
            boxes=(np.stack(seq.boxes) if seq.boxes
                   else np.zeros((0, t, 4), np.float32)),
            uid=(np.stack(seq.uid) if seq.uid
                 else np.zeros((0, t), np.int32)),
            emit=(np.stack(seq.emit) if seq.emit
                  else np.zeros((0, t), bool)),
            cls=((np.stack(seq.cls) if seq.cls
                  else np.zeros((0, t), np.int32))
                 if self._need_class else None),
        ))

    def _check_uid_headroom(self) -> None:
        """Guard the per-lane int32 uid counter (``SlotPool.next_uid``).

        ``reset_ragged`` resets the counter to ``uid_start`` on every lane
        recycle, so under normal serving the counter is bounded by one
        sequence's birth count.  A single monster sequence can still run
        it toward int32 overflow; rather than silently wrapping onto uids
        that may *still be alive*, fail loudly with the remediation.  The
        check fetches the ``[L]`` int32 counter row each chunk (a tiny
        cross-device gather in mesh mode) — negligible next to the chunk's
        own output transfer, and the chunk boundary is already a host
        sync point.
        """
        next_uid = np.asarray(self._state.pool.next_uid)
        if next_uid.size and int(next_uid.max()) > slots.UID_LIMIT:
            lane = int(next_uid.argmax())
            raise RuntimeError(
                f"track uid counter on lane {lane} exceeded "
                f"slots.UID_LIMIT ({slots.UID_LIMIT}): a single sequence "
                f"allocated ~2**31 track ids.  uids are int32 and only "
                f"reset when the lane is recycled (reset_ragged); split "
                f"the sequence or re-admit it to reset its uid namespace.")

    def pop_ready(self) -> list[SequenceTracks]:
        """Release every finished sequence whose turn has come (submission
        order), **without dispatching anything** — the drain path for
        results that finalized off the chunk path (e.g. zero-frame
        sequences completed at ``submit`` time)."""
        return self._ready.pop_ready()

    def run_chunk(self) -> list[SequenceTracks]:
        """Dispatch (at most) one planned chunk and release whatever
        finished — the service front-end's pump unit (DESIGN.md §11).
        Every return is a chunk boundary: :meth:`export_state` is legal
        immediately after."""
        return self._run_chunk()

    # ------------------------------------------- checkpoint/restore hooks
    # (DESIGN.md §11: the full serving state crosses the checkpoint in a
    # topology-NEUTRAL form — device state in the engine layout via the
    # exact layout inverses, host bookkeeping as numpy arrays + JSON-able
    # meta — so a server restarted on a different execution strategy,
    # stream-block padding, or device mesh resumes bit-exactly.)
    STATE_SCHEMA = 1

    def _engine_signature(self) -> dict:
        """The semantic engine config a checkpoint must agree on.  The
        execution strategy (use_kernels / chunk_kernel / block_b / mesh)
        is deliberately absent: every path computes the same tracker
        (track identities exact, coordinates to float tolerance —
        tests/test_oracle_parity.py), so a checkpoint may resume on any
        of them; resuming on the SAME strategy is bit-exact."""
        cfg = self.engine.config
        return {"max_trackers": cfg.max_trackers,
                "max_detections": cfg.max_detections,
                "iou_threshold": cfg.iou_threshold,
                "max_age": cfg.max_age, "min_hits": cfg.min_hits,
                "assoc": cfg.assoc, "dtype": cfg.dtype,
                "num_classes": cfg.num_classes, "cost": repr(cfg.cost)}

    def _engine_layout_state(self):
        """Resident device state -> engine-layout ``SortState`` on host."""
        if self._sharding is not None:
            state = self._sharding._to_engine(self._state)
        elif self.engine.config.use_kernels:
            state = sort_state_of(self._state, self.num_lanes)
        else:
            state = self._state
        return jax.tree.map(np.asarray, jax.device_get(state))

    def _seq_arrays(self, seq: _Seq) -> dict:
        t = self.engine.config.max_trackers
        pre = f"seq/{seq.index}"
        arrays = {
            f"{pre}/det_boxes": seq.det_boxes,
            f"{pre}/det_mask": seq.det_mask,
            f"{pre}/out_boxes": (np.stack(seq.boxes) if seq.boxes
                                 else np.zeros((0, t, 4), np.float32)),
            f"{pre}/out_uid": (np.stack(seq.uid) if seq.uid
                               else np.zeros((0, t), np.int32)),
            f"{pre}/out_emit": (np.stack(seq.emit) if seq.emit
                                else np.zeros((0, t), bool)),
        }
        if seq.det_class is not None:
            arrays[f"{pre}/det_class"] = seq.det_class
        if seq.det_embed is not None:
            arrays[f"{pre}/det_embed"] = seq.det_embed
        if self._need_class:
            arrays[f"{pre}/out_cls"] = (np.stack(seq.cls) if seq.cls
                                        else np.zeros((0, t), np.int32))
        return arrays

    def export_state(self) -> tuple[dict, dict]:
        """Snapshot the COMPLETE serving state at a chunk boundary.

        Returns ``(meta, arrays)``: ``meta`` is JSON-able (schema,
        engine signature, lane occupancy/cursors, FIFO queue order,
        reorder-buffer watermark, elastic ladder position, counters);
        ``arrays`` is a flat ``{path: np.ndarray}`` dict holding the
        engine-layout device state (``lane/...`` — per-lane Kalman
        means/covariances, lifecycle pools, **uid namespaces**), every
        live sequence's inputs + partially accumulated outputs
        (``seq/<i>/...``), and finished-but-unreleased results
        (``done/<i>/...``).  :meth:`import_state` consumes the pair;
        everything a resumed scheduler needs to continue **bit-exactly**
        is inside (tests/test_scheduler.py, tests/test_serving.py).
        """
        live = [s for s in self._occupant if s is not None] \
            + list(self._pending)
        meta = {
            "schema": self.STATE_SCHEMA,
            "engine": self._engine_signature(),
            "max_dets": self.max_dets,
            "num_lanes": self.num_lanes,
            "occupant": [s.index if s is not None else None
                         for s in self._occupant],
            "cursor": [int(c) for c in self._cursor],
            "pending": [s.index for s in self._pending],
            "num_submitted": self._num_submitted,
            "ready_next": self._ready.next_index,
            "held": [int(i) for i in self._ready.held_indices],
            "shrink_target": self._shrink_target,
            "shrink_votes": self._shrink_votes,
            "forced_width": self._forced_width,
            "counters": {"frames_processed": self.frames_processed,
                         "lane_steps": self.lane_steps,
                         "chunks_run": self.chunks_run},
            "admissions": [list(a) for a in self.admissions],
            "seqs": {str(s.index): {"name": s.name} for s in live},
            "done": {str(i): self._ready.peek(i).name
                     for i in self._ready.held_indices},
        }
        from repro.ckpt.checkpoint import flatten_with_paths
        keys, leaves, _ = flatten_with_paths(self._engine_layout_state())
        arrays = {f"lane/{k}": np.asarray(leaf)
                  for k, leaf in zip(keys, leaves)}
        for seq in live:
            arrays.update(self._seq_arrays(seq))
        for i in self._ready.held_indices:
            tr = self._ready.peek(i)
            arrays[f"done/{i}/boxes"] = tr.boxes
            arrays[f"done/{i}/uid"] = tr.uid
            arrays[f"done/{i}/emit"] = tr.emit
            if tr.cls is not None:
                arrays[f"done/{i}/cls"] = tr.cls
        return meta, arrays

    def _rebuild_seq(self, idx: int, name: str, arrays: dict) -> _Seq:
        pre = f"seq/{idx}"
        missing = [k for k in (f"{pre}/det_boxes", f"{pre}/det_mask",
                               f"{pre}/out_boxes", f"{pre}/out_uid",
                               f"{pre}/out_emit")
                   if k not in arrays]
        if missing:
            raise ValueError(f"checkpoint is missing sequence leaves "
                             f"{missing} for live sequence {name!r}")
        db = np.asarray(arrays[f"{pre}/det_boxes"], np.float32)
        dm = np.asarray(arrays[f"{pre}/det_mask"], bool)
        if dm.ndim != 2 or dm.shape[1] != self.max_dets:
            raise ValueError(
                f"sequence {name!r}: checkpointed detection budget "
                f"{dm.shape} does not match this scheduler's "
                f"max_dets={self.max_dets}")
        dc = arrays.get(f"{pre}/det_class")
        de = arrays.get(f"{pre}/det_embed")
        if self._need_class and dc is None:
            raise ValueError(f"sequence {name!r}: checkpoint carries no "
                             f"det_class but this engine partitions classes")
        if self._need_embed and de is None:
            raise ValueError(f"sequence {name!r}: checkpoint carries no "
                             f"det_embed but this engine's cost needs it")
        seq = _Seq(idx, name, db, dm,
                   det_class=(None if dc is None
                              else np.asarray(dc, np.int32)),
                   det_embed=(None if de is None
                              else np.asarray(de, np.float32)))
        seq.boxes = [np.array(a) for a in arrays[f"{pre}/out_boxes"]]
        seq.uid = [np.array(a) for a in arrays[f"{pre}/out_uid"]]
        seq.emit = [np.array(a) for a in arrays[f"{pre}/out_emit"]]
        if self._need_class:
            seq.cls = [np.array(a) for a in arrays[f"{pre}/out_cls"]]
        return seq

    def import_state(self, meta: dict, arrays: dict) -> None:
        """Rebuild the full serving state from :meth:`export_state`'s
        snapshot (typically round-tripped through ``repro.ckpt``).

        Validates before touching anything: schema, the semantic engine
        signature, the detection budget, and that the checkpointed lane
        width is on this scheduler's ladder — so an elastic-restart
        mismatch is a diagnosable ``ValueError``, not corrupted serving.
        The device state re-enters through the exact engine-layout
        inverses (and, in mesh mode, is re-placed with this topology's
        ``NamedSharding``), so a same-strategy resume's per-sequence
        outputs are bit-identical to an uninterrupted run; a resume onto
        a different execution strategy matches it the way the strategies
        match each other — identities exact, coordinates allclose.
        """
        if meta.get("schema") != self.STATE_SCHEMA:
            raise ValueError(f"unsupported scheduler state schema "
                             f"{meta.get('schema')!r} (this build speaks "
                             f"{self.STATE_SCHEMA})")
        sig = self._engine_signature()
        if meta.get("engine") != sig:
            diff = {k: (meta.get("engine", {}).get(k), sig[k])
                    for k in sig if meta.get("engine", {}).get(k) != sig[k]}
            raise ValueError(
                f"checkpointed engine config does not match this "
                f"scheduler's (checkpoint vs here): {diff}")
        if int(meta["max_dets"]) != self.max_dets:
            raise ValueError(f"checkpoint max_dets={meta['max_dets']} vs "
                             f"this scheduler's {self.max_dets}")
        width = int(meta["num_lanes"])
        if width not in self.ladder:
            raise ValueError(
                f"checkpointed lane width {width} is not on this "
                f"scheduler's ladder {self.ladder} — construct the "
                f"scheduler with a ladder covering the checkpoint "
                f"(elastic-restart width mismatch)")

        # device state: engine layout -> this topology's resident layout
        from repro.ckpt.checkpoint import flatten_with_paths
        like = self.engine.init(width)
        keys, leaves, treedef = flatten_with_paths(like)
        missing = [k for k in keys if f"lane/{k}" not in arrays]
        if missing:
            extra = sorted(k for k in arrays if k.startswith("lane/"))
            raise ValueError(f"checkpoint is missing device-state leaves "
                             f"{missing}; it carries {extra}")
        vals = []
        for k, leaf in zip(keys, leaves):
            arr = np.asarray(arrays[f"lane/{k}"])
            want = tuple(np.shape(leaf))
            if tuple(arr.shape) != want:
                raise ValueError(f"device-state leaf {k}: checkpoint shape "
                                 f"{tuple(arr.shape)} != expected {want}")
            vals.append(jnp.asarray(
                arr.astype(np.dtype(leaf.dtype), copy=False)))
        eng_state = jax.tree.unflatten(treedef, vals)
        if self.mesh is not None:
            sharding = self._sharding_for(width)
            self._state = sharding.place_engine_state(eng_state)
            self._sharding = sharding
        elif self.engine.config.use_kernels:
            self._state = lane_state_of(eng_state, self.engine._block_s)
        else:
            self._state = eng_state

        # host bookkeeping: occupancy, FIFO order, reorder buffer, elastic
        seqs = {int(i): self._rebuild_seq(int(i), info["name"], arrays)
                for i, info in meta["seqs"].items()}
        self.num_lanes = width
        self._occupant = [seqs[i] if i is not None else None
                          for i in meta["occupant"]]
        self._cursor = [int(c) for c in meta["cursor"]]
        self._pending = collections.deque(seqs[i] for i in meta["pending"])
        self._num_submitted = int(meta["num_submitted"])
        self._ready = ReorderBuffer(start=int(meta["ready_next"]))
        for i in meta["held"]:
            cls = arrays.get(f"done/{i}/cls")
            self._ready.put(int(i), SequenceTracks(
                name=meta["done"][str(i)],
                boxes=np.asarray(arrays[f"done/{i}/boxes"], np.float32),
                uid=np.asarray(arrays[f"done/{i}/uid"], np.int32),
                emit=np.asarray(arrays[f"done/{i}/emit"], bool),
                cls=(np.asarray(cls, np.int32)
                     if cls is not None else None)))
        self._shrink_target = (None if meta["shrink_target"] is None
                               else int(meta["shrink_target"]))
        self._shrink_votes = int(meta["shrink_votes"])
        self._forced_width = (None if meta["forced_width"] is None
                              else int(meta["forced_width"]))
        c = meta["counters"]
        self.frames_processed = int(c["frames_processed"])
        self.lane_steps = int(c["lane_steps"])
        self.chunks_run = int(c["chunks_run"])
        self.admissions = [tuple(a) for a in meta["admissions"]]

    def drain(self) -> list[SequenceTracks]:
        """Run chunks until no step work remains, then release everything
        buffered; returns all newly finished sequences in submission
        order.  Never dispatches an empty chunk."""
        results = []
        while self._has_step_work:
            results.extend(self._run_chunk())
        results.extend(self.pop_ready())
        return results

    def run(self) -> list[SequenceTracks]:
        """Process every submitted sequence to completion (drain), returning
        their track streams **in submission order**."""
        return self.drain()
