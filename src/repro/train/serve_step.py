"""Serving steps: prefill + single-token decode, and a continuous-batching
driver built on the same slot pool as the SORT trackers.

The decode request batch is the "stream axis" of the paper: requests are
independent, shard over ``(pod, data)``, and the only state carried between
steps is per-slot (KV cache / SSM state) — exactly a tracker's Kalman state.
``ServeLoop`` reuses :mod:`repro.core.slots` for admission/eviction.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core import slots as slot_lib


def make_prefill(model, par, cache_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, par, cache_len)
    return prefill


def make_decode_step(model, par, sample: str = "greedy"):
    """One decode step for the whole request batch: logits -> next token."""
    def step(params, token, pos, caches, rng=None):
        logits, caches = model.decode(params, token, pos, caches, par)
        logits = logits[:, -1]
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(rng, logits).astype(jnp.int32)
        return nxt[:, None], pos + 1, caches
    return step


@dataclasses.dataclass
class ServeLoop:
    """Continuous batching: fixed decode slots, immediate backfill on EOS.

    Host-side driver (python loop) around the jitted decode step — mirrors
    the paper's throughput scaling: the device step is always dense over
    ``num_slots`` lanes; lifecycle churn happens in the slot pool.
    """
    model: Any
    params: Any
    par: Any
    num_slots: int
    cache_len: int
    eos_id: int = 1

    def __post_init__(self):
        self.pool = slot_lib.init_pool((), self.num_slots)
        self.caches = self.model.init_caches(self.params, self.num_slots,
                                             self.cache_len)
        self.token = jnp.zeros((self.num_slots, 1), jnp.int32)
        self.pos = jnp.zeros((self.num_slots,), jnp.int32)
        self._step = jax.jit(make_decode_step(self.model, self.par))
        self.outputs: dict[int, list] = {}
        self._queue: list[list[int]] = []

    def submit(self, prompt_tokens: list[int]):
        self._queue.append(prompt_tokens)

    def _admit(self):
        while self._queue:
            free = ~self.pool.alive
            want = jnp.zeros((len(free),), bool).at[0].set(True)
            slot_for = slot_lib.assign_slots(free, want)
            s = int(slot_for[0])
            if s < 0:
                return  # no free slot: natural back-pressure
            prompt = self._queue.pop(0)
            self.pool = slot_lib.birth(self.pool, slot_for)
            uid = int(self.pool.uid[s])
            # single-sequence prefill into this slot's cache rows
            pf = make_prefill(self.model, self.par, self.cache_len)
            logits, cache1 = pf(self.params,
                                {"tokens": jnp.asarray([prompt], jnp.int32)})
            self.caches = jax.tree.map(
                lambda c, c1: _write_slot(c, c1, s), self.caches, cache1)
            self.token = self.token.at[s, 0].set(
                int(jnp.argmax(logits[0, -1])))
            self.pos = self.pos.at[s].set(len(prompt))
            self.outputs[uid] = [int(self.token[s, 0])]

    def step(self):
        """One dense decode step over all slots; evict finished sequences."""
        self._admit()
        self.token, self.pos, self.caches = self._step(
            self.params, self.token, self.pos, self.caches)
        alive = self.pool.alive
        for s in range(self.num_slots):
            if bool(alive[s]):
                uid = int(self.pool.uid[s])
                t = int(self.token[s, 0])
                self.outputs.setdefault(uid, []).append(t)
        done = alive & ((self.token[:, 0] == self.eos_id)
                        | (self.pos >= self.cache_len - 1))
        self.pool = slot_lib.tick(self.pool, alive & ~done, max_age=0)
        return {int(self.pool.uid[s]): self.outputs.get(int(self.pool.uid[s]))
                for s in range(self.num_slots) if bool(alive[s])}


def _write_slot(cache_all, cache_one, s: int):
    """Copy a single-sequence cache into slot ``s`` of the batched cache.

    Handles both stacked ([L, B, ...]) and unstacked ([B, ...]) leaves by
    matching rank: cache_one's batch dim is 1 where cache_all's is B.
    """
    for axis in range(cache_all.ndim):
        if (cache_one.shape[axis] == 1 and cache_all.shape[axis] != 1
                and cache_all.shape[:axis] == cache_one.shape[:axis]):
            idx = [slice(None)] * cache_all.ndim
            idx[axis] = s
            src = jnp.squeeze(cache_one, axis=axis)
            return cache_all.at[tuple(idx)].set(src.astype(cache_all.dtype))
    return cache_all
