"""AdamW + schedules + global-norm clipping — pure JAX, no optax.

State layout mirrors the param tree (``m``/``v`` per leaf in f32); the
sharding of optimizer state follows the param PartitionSpecs 1:1, so FSDP
shards the moments exactly like the weights (ZeRO style).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: dict
    v: dict
    count: jnp.ndarray


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
        return cfg.lr * warm * frac
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw(cfg: AdamWConfig):
    """Returns (init_fn, update_fn)."""
    schedule = cosine_schedule(cfg)

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params),
                        count=jnp.zeros((), jnp.int32))

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        count = state.count + 1
        b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
        lr = schedule(count)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = cfg.b1 * m + (1 - cfg.b1) * g
            v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
            mhat = m2 / b1c
            vhat = v2 / b2c
            step = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if p.ndim >= 2:  # decay matrices only (norm/bias exempt)
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), m2, v2

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return updates, OptState(new_m, new_v, count), \
            {"grad_norm": gnorm, "lr": lr}

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
