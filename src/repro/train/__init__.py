from .optimizer import AdamWConfig, adamw, cosine_schedule  # noqa: F401
from .train_step import TrainState, make_train_step  # noqa: F401
from .serve_step import make_decode_step, make_prefill  # noqa: F401
