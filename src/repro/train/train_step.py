"""Training step factory: grads + AdamW under pjit, with optional
microbatch accumulation and compressed cross-pod gradient reduction.

Distribution model (DESIGN.md §9):

* intra-pod: pjit auto-sharding — batch over ``data``, params FSDP over
  ``data`` + TP over ``model`` (XLA inserts the all-gathers/reduce-scatters);
* cross-pod: either (a) the same pjit program with batch over
  ``(pod, data)`` — XLA emits one fused all-reduce over both axes — or
  (b) ``compress_pods=True``: the step is shard_mapped over ``pod`` only
  (``data``/``model`` stay auto), gradients are bf16-compressed before the
  explicit cross-pod ``psum`` — halving the slowest (DCN) wire bytes.
  Compression error feedback is carried in the optimizer state.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .optimizer import AdamWConfig, adamw, apply_updates


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_state(params, opt_cfg: AdamWConfig) -> TrainState:
    opt_init, _ = adamw(opt_cfg)
    return TrainState(params=params, opt_state=opt_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, par, opt_cfg: AdamWConfig,
                    microbatches: int = 1, compress_pods: bool = False):
    """Returns ``step(state, batch) -> (state, metrics)`` (to be jitted by
    the caller with in/out shardings)."""
    _, opt_update = adamw(opt_cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch, par)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l,
                    jax.tree.map(jnp.add, grad_acc, g)), None

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0), zero), mbs)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def apply(state: TrainState, loss, grads):
        updates, opt_state, om = opt_update(grads, state.opt_state,
                                            state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, **om}
        return TrainState(params, opt_state, state.step + 1), metrics

    if not compress_pods:
        def step(state: TrainState, batch):
            loss, grads = grads_of(state.params, batch)
            return apply(state, loss, grads)
        return step

    # ---- compressed cross-pod DP: manual over 'pod', auto elsewhere ----
    mesh = par.mesh
    assert mesh is not None and "pod" in mesh.shape, \
        "compress_pods requires a multi-pod mesh"
    npods = mesh.shape["pod"]

    def pod_step(state: TrainState, batch):
        def inner(st, b):
            loss, grads = grads_of(st.params, b)
            # bf16 compression before the cross-pod (DCN) all-reduce:
            # halves wire bytes on the slowest link in the system.
            cgrads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            cgrads = jax.lax.psum(cgrads, "pod")
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / npods, cgrads)
            loss = jax.lax.psum(loss, "pod") / npods
            return apply(st, loss, grads)

        from repro.compat import shard_map
        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P("pod")),   # state replicated over pod; batch split
            out_specs=(P(), P()),
            check_vma=False,
            axis_names=frozenset({"pod"}),  # 'data'/'model' stay auto-sharded
        )(state, batch)

    return pod_step
