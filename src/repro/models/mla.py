"""Multi-head Latent Attention (DeepSeek-V2, MiniCPM3).

Keys/values are compressed into a per-token latent ``c_kv`` of rank
``kv_lora_rank`` plus a single shared RoPE key head; the decode cache stores
only ``kv_lora_rank + qk_rope_head_dim`` floats per token (576 for
DeepSeek-V2 vs 2 * 128 * 128 for dense MHA — a 57x KV-cache reduction that
directly multiplies the stream/request batch each chip can hold; see
DESIGN.md §5).

Decode uses the paper's *matrix absorption*: ``q_nope`` is mapped through
``W_uk`` into latent space so attention scores are taken directly against
the compressed cache — no per-token key expansion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import NEG_INF, blockwise_attention, _ring_write
from .config import ModelConfig
from .layers import ParamBuilder, apply_rope, linear, rms_norm, rope_freqs


def mla_init(pb: ParamBuilder, cfg: ModelConfig):
    sub = ParamBuilder(pb.key(), pb.dtype)
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    if cfg.q_lora_rank:
        sub.dense("q_a", cfg.d_model, cfg.q_lora_rank, "embed", "lora")
        sub.norm("q_a_norm", cfg.q_lora_rank)
        sub.dense("q_b", cfg.q_lora_rank, h * (dn + dr), "lora", "heads")
    else:
        sub.dense("q", cfg.d_model, h * (dn + dr), "embed", "heads")
    sub.dense("kv_a", cfg.d_model, cfg.kv_lora_rank + dr, "embed", None)
    sub.norm("kv_a_norm", cfg.kv_lora_rank)
    sub.dense("kv_b", cfg.kv_lora_rank, h * (dn + dv), "lora", "heads")
    sub.dense("o", h * dv, cfg.d_model, "heads", "embed")
    p, s = sub.build()
    pb.sub("attn", p, s)
    return pb


def _project_q(p, x, cfg: ModelConfig):
    b, l, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = linear(rms_norm(linear(x, p["q_a"]), p["q_a_norm"]["scale"],
                            cfg.rms_norm_eps), p["q_b"])
    else:
        q = linear(x, p["q"])
    q = q.reshape(b, l, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def _compress_kv(p, x, cfg: ModelConfig, positions):
    """Returns the cacheable pair (c_kv normalized, k_rope rotated)."""
    dr = cfg.qk_rope_head_dim
    kv = linear(x, p["kv_a"])
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_a_norm"]["scale"],
                    cfg.rms_norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]  # single shared head
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0]
    return c_kv, k_rope


def mla_forward(p, x, cfg: ModelConfig, positions, *, window=None,
                q_chunk: int = 512, kv_chunk: int = 1024):
    """Train/prefill: expand k/v per head, run blockwise attention with the
    rope-key folded in as extra head dims (score = qn.kn + qr.kr)."""
    b, l, _ = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    q_nope, q_rope = _project_q(p, x, cfg)
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    c_kv, k_rope = _compress_kv(p, x, cfg, positions)
    kv = linear(c_kv, p["kv_b"]).reshape(b, l, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    # fold the shared rope key into per-head key vectors: K = [k_nope, k_rope]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, l, h, dr))], axis=-1)
    # pad v to qk dim so blockwise attention sees uniform head dims, slice
    # after.  blockwise scales by (dn+dr)^-0.5 == DeepSeek's softmax scale.
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    out = blockwise_attention(q, k, v_pad, positions,
                              causal=cfg.causal, window=window,
                              q_chunk=min(q_chunk, l), kv_chunk=min(kv_chunk, l))
    out = out[..., :dv]
    return linear(out.reshape(b, l, h * dv), p["o"])


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype)}


def mla_decode(p, x, cache, cfg: ModelConfig, pos, *, window=None,
               full_cache_len=None):
    """Absorbed single-token decode against the compressed cache."""
    b = x.shape[0]
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    r = cfg.kv_lora_rank
    q_nope, q_rope = _project_q(p, x, cfg)            # [B, 1, H, dn/dr]
    cos, sin = rope_freqs(dr, cfg.rope_theta, pos[:, None])
    q_rope = apply_rope(q_rope, cos, sin)[:, 0]       # [B, H, dr]
    c_kv, k_rope = _compress_kv(p, x, cfg, pos[:, None])

    ck = _ring_write(cache["c_kv"], c_kv[:, 0], pos)
    kr = _ring_write(cache["k_rope"], k_rope[:, 0], pos)

    # absorption: q_lat[b,h,r] = sum_dn q_nope[b,h,dn] * W_uk[r, h, dn]
    w_kv = p["kv_b"]["w"].reshape(r, h, dn + dv)
    w_uk, w_uv = w_kv[..., :dn], w_kv[..., dn:]
    # §Perf C3: keep the CACHE-sized operands in their storage dtype and
    # accumulate in f32 via preferred_element_type — upcasting ck/kr to f32
    # triples decode HBM traffic (read bf16 + write/read f32 copies).
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(ck.dtype)

    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bhr,bcr->bhc", q_lat, ck,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bcd->bhc", q_rope.astype(kr.dtype), kr,
                      preferred_element_type=jnp.float32)) * scale
    c = cache["c_kv"].shape[1]
    valid = (jnp.arange(c)[None, :] <= pos[:, None])
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhc,bcr->bhr", w.astype(ck.dtype), ck,
                     preferred_element_type=jnp.float32)          # latent ctx
    o = jnp.einsum("bhr,rhd->bhd", ctx.astype(x.dtype),
                   w_uv.astype(x.dtype))                          # expand to v
    y = linear(o.reshape(b, 1, h * dv).astype(x.dtype), p["o"])
    return y, {"c_kv": ck, "k_rope": kr}
