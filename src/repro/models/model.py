"""Model assembly: embeddings + modality frontends + trunk + LM head.

``build_model(cfg)`` returns a :class:`Model` of pure functions:

* ``init(key) -> (params, specs)``
* ``forward(params, batch, par) -> logits``          (train / encode shape)
* ``loss(params, batch, par) -> scalar``
* ``prefill(params, batch, par, cache_len) -> (logits, caches)``
* ``decode(params, token, pos, caches, par) -> (logits, caches)``

Modality frontends (paper-pool rule): ``[audio]``/``[vlm]`` archs take
*precomputed* frame/patch embeddings via ``input_specs`` — only the trainable
projection (LLaVA's mm-projector, HuBERT's mask embedding) is real.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import transformer
from .config import ModelConfig
from .layers import ParamBuilder, linear, rms_norm, softmax_xent
from .transformer import Parallel


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_caches: Callable


def build_model(cfg: ModelConfig) -> Model:
    def init(key):
        pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
        pb.table("embed", (cfg.padded_vocab, cfg.d_model),
                 ("vocab", "embed"))
        if cfg.modality == "vision":
            sub = ParamBuilder(pb.key(), pb.dtype)
            sub.dense("fc1", cfg.frontend_dim, cfg.d_model, None, "embed")
            sub.dense("fc2", cfg.d_model, cfg.d_model, "embed", None)
            mp, ms = sub.build()
            pb.sub("mm_projector", mp, ms)
        if cfg.modality == "audio":
            pb.raw("mask_emb", 0.02 * jax.random.normal(
                pb.key(), (cfg.d_model,), pb.dtype), (None,))
        trunk, trunk_specs = transformer.stack_init(pb.key(), cfg)
        pb.sub("trunk", trunk, trunk_specs)
        pb.norm("final_norm", cfg.d_model)
        if not cfg.tie_embeddings:
            pb.dense("lm_head", cfg.d_model, cfg.padded_vocab,
                     "embed", "vocab")
        return pb.build()

    # ----------------------------------------------------------- embedding
    def embed_batch(params, batch):
        dt = jnp.dtype(cfg.dtype)
        table = params["embed"]

        if cfg.modality == "audio":
            feats = batch["feats"].astype(dt)                  # [B, L, D]
            if "mask_spans" in batch:
                m = batch["mask_spans"][..., None]
                feats = jnp.where(m, params["mask_emb"].astype(dt), feats)
            h = feats
        elif cfg.modality == "vision":
            tok = jnp.take(table, batch["tokens"], axis=0).astype(dt)
            patches = batch["patches"].astype(dt)              # [B, Np, F]
            mp = params["mm_projector"]
            pe = linear(jax.nn.gelu(linear(patches, mp["fc1"])), mp["fc2"])
            h = jnp.concatenate([pe, tok], axis=1)
        else:
            h = jnp.take(table, batch["tokens"], axis=0).astype(dt)

        b, l = h.shape[0], h.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32),
                                         (b, l))
        return h, positions

    def head(params, h):
        h = rms_norm(h, params["final_norm"]["scale"], cfg.rms_norm_eps)
        if cfg.tie_embeddings:
            return h @ params["embed"].astype(h.dtype).T
        return linear(h, params["lm_head"])

    # ------------------------------------------------------------ training
    def forward(params, batch, par: Parallel = Parallel()):
        h, positions = embed_batch(params, batch)
        h = transformer.stack_forward(params["trunk"], h, cfg, positions, par)
        return head(params, h)

    def loss(params, batch, par: Parallel = Parallel()):
        logits = forward(params, batch, par)
        labels = batch["labels"]
        mask = batch.get("loss_mask",
                         jnp.ones(labels.shape, jnp.float32))
        if cfg.modality == "vision":  # logits cover [patches, tokens]
            logits = logits[:, -labels.shape[1]:]
        return softmax_xent(logits, labels, mask, cfg.vocab_size)

    # ------------------------------------------------------------- serving
    def prefill(params, batch, par: Parallel = Parallel(),
                cache_len: int | None = None):
        h, positions = embed_batch(params, batch)
        cache_len = cache_len or cfg.max_seq_len
        h, caches = transformer.stack_prefill(
            params["trunk"], h, cfg, positions, par, cache_len,
            jnp.dtype(cfg.dtype))
        return head(params, h[:, -1:]), caches

    def decode(params, token, pos, caches, par: Parallel = Parallel()):
        dt = jnp.dtype(cfg.dtype)
        h = jnp.take(params["embed"], token, axis=0).astype(dt)  # [B, 1, D]
        h, caches = transformer.stack_decode(params["trunk"], h, caches, cfg,
                                             pos, par)
        return head(params, h), caches

    def init_caches(params, batch: int, cache_len: int):
        return transformer.init_caches(params["trunk"], cfg, batch,
                                       cache_len, jnp.dtype(cfg.dtype))

    return Model(cfg, init, forward, loss, prefill, decode, init_caches)
