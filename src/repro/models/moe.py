"""Mixture-of-Experts FFN (DeepSeek-V2 style: shared + routed, top-k).

Expert parallelism is implemented with ``jax.shard_map`` and explicit
``all_to_all`` collectives — the production EP pattern:

  * tokens live on the ``(pod, data)`` axes, experts on ``model``;
  * each shard routes its local tokens, packs them into per-expert capacity
    buffers with a *local* one-hot rank (no global sort, no cross-shard
    scatter), and exchanges buffers along ``model`` with one tiled
    ``all_to_all`` each way;
  * expert weights are stored ``[E, D, F]`` sharded (E over ``model``,
    D/F over ``data``) and FSDP-gathered over ``data`` at use.

Over-capacity tokens are dropped (standard capacity-factor policy); their
combine weight is zero so the residual path carries them unchanged.

When no mesh is active (CPU tests) the same math runs unsharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import ParamBuilder


def moe_init(pb: ParamBuilder, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_routed_experts
    sub = ParamBuilder(pb.key(), pb.dtype)
    sub.dense("router", d, e, "embed", None, scale=0.02)
    scale = 1.0 / (d ** 0.5)
    for nm, shape, axes in (
            ("w1", (e, d, f), ("experts", "embed", None)),
            ("w3", (e, d, f), ("experts", "embed", None)),
            ("w2", (e, f, d), ("experts", "ff_exp", None))):
        sub.table(nm, shape, axes, scale=scale)
    if cfg.n_shared_experts:
        from .layers import swiglu_init
        swiglu_init(sub, "shared", d, cfg.n_shared_experts * f)
    p, s = sub.build()
    pb.sub("moe", p, s)
    return pb


def route(p, x, cfg: ModelConfig):
    """Router: softmax over routed experts, top-k, renormalized weights."""
    logits = (x.astype(jnp.float32)
              @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i, probs


def _expert_ffn(w1, w3, w2, x):
    """Batched per-expert SwiGLU: ``x [E, C, D]`` -> ``[E, C, D]``."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w1.astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", x, w3.astype(x.dtype))
    return jnp.einsum("ecf,efd->ecd", h * u, w2.astype(x.dtype))


def _pack(xf, top_i, top_p, e: int, cap: int):
    """Pack tokens into per-expert capacity buffers (local, no collectives).

    ``xf [N, D]``; ``top_i/top_p [N, K]``.  Returns buffer ``[E, cap, D]``,
    plus gather metadata to unpack.  Slot rank = running count of earlier
    (token, k) pairs routed to the same expert.
    """
    n, k = top_i.shape
    flat_e = top_i.reshape(-1)                                   # [N*K]
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)              # [N*K, E]
    # rank of each (token, k) pair within its expert = exclusive running count
    rank = jnp.einsum("ne,ne->n", jnp.cumsum(oh, axis=0) - oh, oh)
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)                            # overflow row
    buf = jnp.zeros((e, cap + 1, xf.shape[-1]), xf.dtype)
    src = jnp.repeat(xf, k, axis=0)
    buf = buf.at[flat_e, slot].set(src)
    return buf[:, :cap], flat_e, slot, keep


def _unpack(buf_out, flat_e, slot, keep, top_p, n: int, k: int):
    """Gather expert outputs back to token order and combine with weights."""
    safe_slot = jnp.minimum(slot, buf_out.shape[1] - 1)
    y = buf_out[flat_e, safe_slot]                               # [N*K, D]
    w = (top_p.reshape(-1) * keep).astype(y.dtype)
    return (y * w[:, None]).reshape(n, k, -1).sum(axis=1)


def moe_ffn_local(p, x, cfg: ModelConfig):
    """Single-device reference path (tests, smoke configs)."""
    b, l, d = x.shape
    xf = x.reshape(-1, d)
    top_p, top_i, _ = route(p, xf, cfg)
    n = xf.shape[0]
    cap = max(int(n * cfg.moe_top_k / cfg.n_routed_experts
                  * cfg.moe_capacity_factor), cfg.moe_top_k)
    buf, flat_e, slot, keep = _pack(xf, top_i, top_p,
                                    cfg.n_routed_experts, cap)
    buf_out = _expert_ffn(p["w1"], p["w3"], p["w2"], buf)
    y = _unpack(buf_out, flat_e, slot, keep, top_p, n, cfg.moe_top_k)
    return y.reshape(b, l, d)


def moe_ffn_ep(p, x, cfg: ModelConfig, mesh, dp_axes: tuple, tp_axis: str,
               fsdp_axis: str = "data"):
    """Expert-parallel path: shard_map + all_to_all over ``tp_axis``.

    Tokens are sharded over ``dp_axes`` (pod x data); expert weights are
    stored (E over ``tp_axis``) x (D/F over ``fsdp_axis``) and gathered over
    the *intra-pod* axis only — cross-pod (DCN) links never carry weights.
    """
    e = cfg.n_routed_experts
    tp = mesh.shape[tp_axis]
    e_loc = e // tp
    assert e_loc * tp == e, (e, tp)
    gather_w = fsdp_axis in mesh.shape and mesh.shape[fsdp_axis] > 1
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]

    # static cost model: move weights (gather) vs. move activations (psum).
    n_tokens_loc = (x.shape[0] // dp_total) * x.shape[1]
    cap_est = max(int(-(-n_tokens_loc // tp) * cfg.moe_top_k / e
                      * cfg.moe_capacity_factor), cfg.moe_top_k)
    act_bytes = 3 * e_loc * tp * cap_est * max(cfg.moe_d_ff, cfg.d_model)
    wgt_bytes = 3 * e_loc * cfg.d_model * cfg.moe_d_ff
    stationary = gather_w and act_bytes < wgt_bytes

    def inner(xl, router, w1, w3, w2):
        bl, l, d = xl.shape
        xf = xl.reshape(-1, d)
        n_loc = xf.shape[0]
        # tokens are replicated over tp_axis at entry: each tp rank takes its
        # contiguous 1/TP slice so every token rides the wire exactly once.
        n_pad = -(-n_loc // tp) * tp
        if n_pad != n_loc:
            xf = jnp.pad(xf, ((0, n_pad - n_loc), (0, 0)))
        n_m = n_pad // tp
        rank = jax.lax.axis_index(tp_axis)
        xm = jax.lax.dynamic_slice_in_dim(xf, rank * n_m, n_m)
        top_p, top_i, _ = route({"router": {"w": router}}, xm, cfg)
        cap = max(int(n_m * cfg.moe_top_k / e * cfg.moe_capacity_factor),
                  cfg.moe_top_k)
        buf, flat_e, slot, keep = _pack(xm, top_i, top_p, e, cap)
        # exchange: my buffers for peer experts <-> peer buffers for mine
        buf = jax.lax.all_to_all(buf.reshape(tp, e_loc, cap, d), tp_axis,
                                 split_axis=0, concat_axis=0, tiled=False)
        #   [TP, E_loc, cap, D] with axis 0 = source peer
        buf = buf.transpose(1, 0, 2, 3).reshape(e_loc, tp * cap, d)
        if gather_w and not stationary:
            # FSDP-gather my experts' weights (intra-pod links)
            w1 = jax.lax.all_gather(w1, fsdp_axis, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, fsdp_axis, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, fsdp_axis, axis=1, tiled=True)
        if gather_w and stationary:
            # §Perf C1 (decode): weights stay sharded; slice the activation
            # D/F dims locally and psum partial products over the fsdp axis —
            # wire bytes scale with the (tiny) token buffer, not the weights.
            r = jax.lax.axis_index(fsdp_axis)
            d_loc, f_loc = w1.shape[1], w2.shape[1]
            xd = jax.lax.dynamic_slice_in_dim(buf, r * d_loc, d_loc, axis=-1)
            h = jax.lax.psum(
                jnp.einsum("ecd,edf->ecf", xd, w1.astype(xd.dtype)),
                fsdp_axis)
            u = jax.lax.psum(
                jnp.einsum("ecd,edf->ecf", xd, w3.astype(xd.dtype)),
                fsdp_axis)
            hu = jax.nn.silu(h) * u
            hf = jax.lax.dynamic_slice_in_dim(hu, r * f_loc, f_loc, axis=-1)
            out = jax.lax.psum(
                jnp.einsum("ecf,efd->ecd", hf, w2.astype(hf.dtype)),
                fsdp_axis)
        else:
            out = _expert_ffn(w1, w3, w2, buf)
        out = out.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, tp_axis, split_axis=0, concat_axis=0,
                                 tiled=False).reshape(e, cap, d)
        ym = _unpack(out, flat_e, slot, keep, top_p, n_m, cfg.moe_top_k)
        # re-replicate over tp_axis (token slices back together)
        y = jax.lax.all_gather(ym, tp_axis, axis=0, tiled=True)[:n_loc]
        return y.reshape(bl, l, d)

    spec_x = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None)
    w_spec = P(tp_axis, fsdp_axis if gather_w else None, None)
    from repro.compat import shard_map
    return shard_map(
        inner, mesh=mesh,
        in_specs=(spec_x, P(None, None), w_spec, w_spec, w_spec),
        out_specs=spec_x,
        check_vma=False,
    )(x, p["router"]["w"], p["w1"], p["w3"], p["w2"])


def moe_ffn(p, x, cfg: ModelConfig, mesh=None, dp_axes=("data",),
            tp_axis="model"):
    """Dispatch to EP or local path; always adds the shared experts."""
    if mesh is not None and mesh.shape.get(tp_axis, 1) > 1 \
            and cfg.n_routed_experts % mesh.shape[tp_axis] == 0:
        y = moe_ffn_ep(p, x, cfg, mesh, dp_axes, tp_axis)
    else:
        y = moe_ffn_local(p, x, cfg)
    if cfg.n_shared_experts:
        from .layers import swiglu
        y = y + swiglu(x, p["shared"])
    return y
