"""Unified layer stack: pre-norm blocks, segment-grouped ``lax.scan``.

The stack is split into *segments* of structurally identical layers (same
FFN kind, same attention window).  Each multi-layer segment is scanned —
compile time stays O(#segments), not O(depth) — and each segment sizes its
own KV cache:

* DeepSeek ``first_k_dense``: a leading dense-FFN segment before the MoE
  segment;
* Hymba global-vs-local attention: global layers get full-length caches,
  sliding-window layers get ring caches of window size — this is what makes
  ``long_500k`` fit in HBM (3 full caches + 29 x 1-KiB-window rings instead
  of 32 full caches).

Remat: every layer body is ``jax.checkpoint``-wrapped (``cfg.remat='full'``)
so blockwise-attention score chunks are recomputed, never stored.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import attention, hybrid, mamba2, mla, moe
from .config import ModelConfig
from .layers import ParamBuilder, rms_norm, swiglu, swiglu_init


@dataclasses.dataclass(frozen=True)
class Parallel:
    """Runtime distribution context (None mesh = single-device math)."""
    mesh: Any = None
    dp_axes: tuple = ("data",)
    tp_axis: str = "model"

    @staticmethod
    def local() -> "Parallel":
        return Parallel()

    def constrain_batch(self, x):
        """Pin the leading (population) axis to (pod, data) — without this,
        SPMD propagation can silently drop batch sharding after the
        vocab-sharded embedding gather and replicate the whole token stream
        on every device (observed: 10x per-device FLOPs)."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        if x.shape[0] % _axes_size(self.mesh, self.dp_axes) != 0:
            return x
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


@dataclasses.dataclass(frozen=True)
class Segment:
    """A run of structurally identical layers."""
    num_layers: int
    use_moe: bool
    window: Optional[int]        # None = full attention


def plan_segments(cfg: ModelConfig) -> list[Segment]:
    """Derive the segment plan from the config."""
    full = None
    win = [full] * cfg.num_layers
    if cfg.sliding_window is not None:
        win = [cfg.sliding_window] * cfg.num_layers
        for i in cfg.global_attn_layers:
            win[i % cfg.num_layers] = full
    use_moe = [cfg.moe and i >= cfg.first_k_dense
               for i in range(cfg.num_layers)]
    segs: list[Segment] = []
    for i in range(cfg.num_layers):
        key = (use_moe[i], win[i])
        if segs and (segs[-1].use_moe, segs[-1].window) == key:
            segs[-1] = dataclasses.replace(segs[-1],
                                           num_layers=segs[-1].num_layers + 1)
        else:
            segs.append(Segment(1, *key))
    return segs


# ----------------------------------------------------------------- layers
def layer_init(key, cfg: ModelConfig, use_moe: bool):
    pb = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
    pb.norm("norm1", cfg.d_model)
    if cfg.block_type == "attn":
        (mla.mla_init if cfg.attn_type == "mla" else attention.gqa_init)(pb, cfg)
    elif cfg.block_type == "ssm":
        mamba2.mamba2_init(pb, cfg)
    elif cfg.block_type == "hybrid":
        hybrid.hybrid_init(pb, cfg)
    else:
        raise ValueError(cfg.block_type)
    if _has_ffn(cfg):
        pb.norm("norm2", cfg.d_model)
        if use_moe:
            moe.moe_init(pb, cfg)
        else:
            swiglu_init(pb, "mlp", cfg.d_model, cfg.d_ff)
    return pb.build()


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.block_type != "ssm" and (cfg.d_ff > 0 or cfg.moe)


def _ffn(p, x, cfg, par):
    h2 = rms_norm(x, p["norm2"]["scale"], cfg.rms_norm_eps)
    if "moe" in p:
        y = moe.moe_ffn(p["moe"], h2, cfg, par.mesh, par.dp_axes, par.tp_axis)
    else:
        y = swiglu(h2, p["mlp"])
    return x + y


def layer_fwd(p, x, cfg: ModelConfig, positions, window, par: Parallel):
    """One block, full-sequence (train / encode shape)."""
    h = rms_norm(x, p["norm1"]["scale"], cfg.rms_norm_eps)
    if cfg.block_type == "attn":
        if cfg.attn_type == "mla":
            mix = mla.mla_forward(p["attn"], h, cfg, positions, window=window)
        else:
            mix = attention.gqa_forward(p["attn"], h, cfg, positions,
                                        window=window)
    elif cfg.block_type == "ssm":
        mix, _, _ = mamba2.mamba2_forward(p["ssm"], h, cfg)
    else:
        mix = hybrid.hybrid_forward(p, h, cfg, positions, window=window)
    x = x + mix
    return _ffn(p, x, cfg, par) if _has_ffn(cfg) else x


def layer_decode(p, x, cache, cfg: ModelConfig, pos, window, par: Parallel):
    """One block, single-token decode."""
    h = rms_norm(x, p["norm1"]["scale"], cfg.rms_norm_eps)
    if cfg.block_type == "attn":
        if cfg.attn_type == "mla":
            mix, cache = mla.mla_decode(p["attn"], h, cache, cfg, pos,
                                        window=window)
        else:
            mix, cache = attention.gqa_decode(p["attn"], h, cache, cfg, pos,
                                              window=window)
    elif cfg.block_type == "ssm":
        mix, cache = mamba2.mamba2_decode(p["ssm"], h, cache, cfg)
    else:
        mix, cache = hybrid.hybrid_decode(p, h, cache, cfg, pos,
                                          window=window)
    x = x + mix
    return (_ffn(p, x, cfg, par) if _has_ffn(cfg) else x), cache


def layer_prefill(p, x, cfg: ModelConfig, positions, window, par: Parallel,
                  cache_cap: int, dtype):
    """Forward + cache construction (prompt length L, cache capacity cap)."""
    h = rms_norm(x, p["norm1"]["scale"], cfg.rms_norm_eps)
    b, l, _ = x.shape
    if cfg.block_type == "attn":
        if cfg.attn_type == "mla":
            mix = mla.mla_forward(p["attn"], h, cfg, positions, window=window)
            c_kv, k_rope = mla._compress_kv(p["attn"], h, cfg, positions)
            cache = mla.init_cache(cfg, b, cache_cap, dtype)
            cache = {"c_kv": cache["c_kv"].at[:, :l].set(c_kv.astype(dtype)),
                     "k_rope": cache["k_rope"].at[:, :l].set(
                         k_rope.astype(dtype))}
        else:
            mix = attention.gqa_forward(p["attn"], h, cfg, positions,
                                        window=window)
            cache = _fill_kv_cache(p["attn"], h, cfg, positions, cache_cap,
                                   window, dtype)
    elif cfg.block_type == "ssm":
        mix, conv_tail, hfin = mamba2.mamba2_forward(p["ssm"], h, cfg)
        cache = {"h": hfin, "conv": conv_tail}
    else:
        a_mix = attention.gqa_forward(p["attn"], h, cfg, positions,
                                      window=window)
        kvc = _fill_kv_cache(p["attn"], h, cfg, positions, cache_cap,
                             window, dtype)
        s_mix, conv_tail, hfin = mamba2.mamba2_forward(p["ssm"], h, cfg)
        mix = hybrid._fuse(p["fuse"], cfg, a_mix, s_mix)
        cache = {"kv": kvc, "ssm": {"h": hfin, "conv": conv_tail}}
    x = x + mix
    return (_ffn(p, x, cfg, par) if _has_ffn(cfg) else x), cache


def _fill_kv_cache(p, h, cfg, positions, cache_cap, window, dtype):
    from .layers import apply_rope, linear, rope_freqs
    b, l, _ = h.shape
    hd = cfg.head_dim_
    k = linear(h, p["k"]).reshape(b, l, cfg.eff_n_kv_heads,
                                  hd)[:, :, :cfg.n_kv_heads]
    v = linear(h, p["v"]).reshape(b, l, cfg.eff_n_kv_heads,
                                  hd)[:, :, :cfg.n_kv_heads]
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    k = apply_rope(k, cos, sin)
    cap = cache_cap if window is None else min(cache_cap, window)
    shape = (b, cap, cfg.n_kv_heads, hd)
    kk, vv = k[:, -cap:], v[:, -cap:]
    slots = positions[:, -kk.shape[1]:] % cap
    rows = jnp.arange(b)[:, None]
    ck = jnp.zeros(shape, dtype).at[rows, slots].set(kk.astype(dtype))
    cv = jnp.zeros(shape, dtype).at[rows, slots].set(vv.astype(dtype))
    return {"k": ck, "v": cv}


def _seg_cache(cfg: ModelConfig, batch: int, cache_cap: int,
               window: Optional[int], dtype):
    cap = cache_cap if window is None else min(cache_cap, window)
    if cfg.block_type == "attn":
        if cfg.attn_type == "mla":
            return mla.init_cache(cfg, batch, cache_cap, dtype)
        return attention.init_cache(
            dataclasses.replace(cfg, sliding_window=window), batch,
            cache_cap, dtype)
    if cfg.block_type == "ssm":
        return mamba2.init_state(cfg, batch, dtype)
    return {"kv": attention.init_cache(
                dataclasses.replace(cfg, sliding_window=window), batch,
                cache_cap, dtype),
            "ssm": mamba2.init_state(cfg, batch, dtype)}


# ------------------------------------------------------------------ stack
def stack_init(key, cfg: ModelConfig):
    """Returns (params, specs): a list of per-segment stacked params."""
    segs = plan_segments(cfg)
    keys = jax.random.split(key, len(segs))
    seg_params, seg_specs = [], []
    for sk, seg in zip(keys, segs):
        if seg.num_layers == 1:
            p, s = layer_init(sk, cfg, seg.use_moe)
            seg_params.append(p)
            seg_specs.append(s)
            continue
        cap = {}

        def _one(k, _seg=seg, _cap=cap):
            p, s = layer_init(k, cfg, _seg.use_moe)
            _cap["s"] = s
            return p

        stacked = jax.vmap(_one)(jax.random.split(sk, seg.num_layers))
        seg_params.append(stacked)
        seg_specs.append(jax.tree.map(
            lambda sp: (None,) + tuple(sp), cap["s"],
            is_leaf=lambda sp: isinstance(sp, tuple)))
    return {"segments": seg_params}, {"segments": seg_specs}


def _maybe_remat(cfg, fn, static_argnums):
    if cfg.remat == "full":
        return jax.checkpoint(fn, static_argnums=static_argnums)
    return fn


def stack_forward(params, x, cfg: ModelConfig, positions, par: Parallel):
    segs = plan_segments(cfg)
    fwd = _maybe_remat(cfg, layer_fwd, (2, 4, 5))
    x = par.constrain_batch(x)
    for seg, p in zip(segs, params["segments"]):
        if seg.num_layers == 1:
            x = par.constrain_batch(fwd(p, x, cfg, positions, seg.window,
                                        par))
        else:
            def body(carry, pl, _seg=seg):
                y = fwd(pl, carry, cfg, positions, _seg.window, par)
                return par.constrain_batch(y), None
            x, _ = lax.scan(body, x, p)
    return x


def stack_decode(params, x, caches, cfg: ModelConfig, pos, par: Parallel):
    segs = plan_segments(cfg)
    new_caches = []
    x = par.constrain_batch(x)
    for seg, p, c in zip(segs, params["segments"], caches["segments"]):
        if seg.num_layers == 1:
            x, c2 = layer_decode(p, x, c, cfg, pos, seg.window, par)
            x = par.constrain_batch(x)
        else:
            def body(carry, inp, _seg=seg):
                pl, cl = inp
                y, c2 = layer_decode(pl, carry, cl, cfg, pos, _seg.window,
                                     par)
                return par.constrain_batch(y), c2
            x, c2 = lax.scan(body, x, (p, c))
        new_caches.append(c2)
    return x, {"segments": new_caches}


def stack_prefill(params, x, cfg: ModelConfig, positions, par: Parallel,
                  cache_len: int, cache_dtype):
    segs = plan_segments(cfg)
    pre = _maybe_remat(cfg, layer_prefill, (2, 4, 5, 6, 7))
    seg_caches = []
    x = par.constrain_batch(x)
    for seg, p in zip(segs, params["segments"]):
        if seg.num_layers == 1:
            x, c = pre(p, x, cfg, positions, seg.window, par, cache_len,
                       cache_dtype)
            x = par.constrain_batch(x)
        else:
            def body(carry, pl, _seg=seg):
                y, c = pre(pl, carry, cfg, positions, _seg.window, par,
                           cache_len, cache_dtype)
                return par.constrain_batch(y), c
            x, c = lax.scan(body, x, p)
        seg_caches.append(c)
    return x, {"segments": seg_caches}


def init_caches(params, cfg: ModelConfig, batch: int, cache_len: int, dtype):
    """Zero caches shaped like what prefill produces / decode exchanges."""
    segs = plan_segments(cfg)
    out = []
    for seg in segs:
        single = _seg_cache(cfg, batch, cache_len, seg.window, dtype)
        if seg.num_layers == 1:
            out.append(single)
        else:
            out.append(jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (seg.num_layers,) + a.shape).copy(), single))
    return {"segments": out}
