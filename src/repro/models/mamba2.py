"""Mamba2 — SSD (state-space duality) block, chunk-parallel scan.

Follows "Transformers are SSMs" (arXiv:2405.21060): per-head scalar decay
``A``, input-dependent ``dt`` (softplus), grouped ``B``/``C`` projections,
causal depthwise conv on the (x, B, C) channels, gated RMSNorm output.

Train/prefill uses the chunked SSD algorithm: within-chunk attention-like
term + cross-chunk recurrent state carried by ``lax.scan`` — O(L) time,
O(L·Q) memory, MXU-friendly (chunk matmuls of size Q x N/P).  Decode is the
O(1) recurrent update; the SSM state plays exactly the role of the Kalman
state in the paper's trackers (fixed-size per-stream state carried across
frames — see DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .config import ModelConfig
from .layers import ParamBuilder, linear, rms_norm


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def mamba2_init(pb: ParamBuilder, cfg: ModelConfig):
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    cd = conv_dim(cfg)
    sub = ParamBuilder(pb.key(), pb.dtype)
    sub.dense("in_proj", d, 2 * di + 2 * g * n + h, "embed", "inner")
    sub.table("conv_w", (cfg.ssm_conv, cd), (None, "inner"), scale=0.1)
    sub.raw("conv_b", jnp.zeros((cd,), pb.dtype), ("inner",))
    sub.raw("a_log", jnp.asarray(np.log(np.linspace(1.0, 16.0, h)), pb.dtype),
            (None,))
    sub.raw("dt_bias", jnp.zeros((h,), pb.dtype), (None,))
    sub.raw("d_skip", jnp.ones((h,), pb.dtype), (None,))
    sub.norm("out_norm", di)
    sub.dense("out_proj", di, d, "inner", "embed")
    p, s = sub.build()
    pb.sub("ssm", p, s)
    return pb


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_dim(cfg)]
    dt = zxbcdt[..., di + conv_dim(cfg):]
    return z, xbc, dt


def _causal_conv(xbc, w, b, carry=None):
    """Depthwise causal conv, width K.  ``xbc [B, L, C]``, ``w [K, C]``.

    ``carry [B, K-1, C]`` holds the previous step's tail for decode; returns
    the new tail so prefill can hand off to decode."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros(xbc.shape[:1] + (k - 1, xbc.shape[-1]), xbc.dtype)
    full = jnp.concatenate([carry, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(k))
    out = jax.nn.silu(out + b.astype(xbc.dtype))
    return out, full[:, -(k - 1):]


def ssd_chunked(x, dt, a, b, c, cfg: ModelConfig, h0=None):
    """Chunk-parallel SSD.

    ``x [B, L, H, P]``, ``dt [B, L, H]`` (post-softplus), ``a [H]`` (negative),
    ``b``/``c`` ``[B, L, G, N]``.  Returns ``y [B, L, H, P]`` and final state
    ``[B, H, N, P]``.
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    q = min(cfg.ssm_chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q
    rep = h // g

    xr = x.reshape(bsz, nc, q, h, p)
    dtr = dt.reshape(bsz, nc, q, h)
    br = b.reshape(bsz, nc, q, g, n)
    cr = c.reshape(bsz, nc, q, g, n)
    # decay logs within chunk
    da = dtr * a.astype(dtr.dtype)                       # [B, NC, Q, H] (<=0)
    cum = jnp.cumsum(da, axis=2)                         # inclusive cumsum

    def chunk_step(hprev, inp):
        xq, dtq, bq, cq, cumq = inp                      # per-chunk slices
        # ---- intra-chunk (attention-like) ----
        # scores[b, h, i, j] = (C_i . B_j)_{g(h)} * exp(cum_i - cum_j) * dt_j
        cb = jnp.einsum("bign,bjgn->bgij", cq, bq,
                        preferred_element_type=jnp.float32)  # [B, G, Q, Q]
        decay = cumq[:, :, None, :] - cumq[:, None, :, :]    # [B, i, j, H]
        mask = jnp.tril(jnp.ones((q, q), bool))
        # mask the EXPONENT, not the exponential: for j > i the decay is
        # positive and exp overflows; where() after exp poisons gradients
        # (inf * 0 = nan in the backward).
        decay = jnp.where(mask[None, :, :, None], decay, -jnp.inf)
        lmat = jnp.exp(decay)
        scores = (cb[:, :, None] * lmat.transpose(0, 3, 1, 2)
                  .reshape(bsz, g, rep, q, q)
                  * dtq.transpose(0, 2, 1).reshape(bsz, g, rep, 1, q))
        y_intra = jnp.einsum("bgrij,bjgrp->bigrp", scores.astype(jnp.float32),
                             xq.reshape(bsz, q, g, rep, p).astype(jnp.float32))
        # ---- inter-chunk: carried state read through C, decayed to i ----
        hh = hprev.reshape(bsz, g, rep, n, p)
        y_inter = jnp.einsum("bign,bgrnp->bigrp", cq.astype(jnp.float32), hh)
        y_inter = y_inter * jnp.exp(cumq).reshape(bsz, q, g, rep)[..., None]
        # ---- state update: decay old state to chunk end, add new inputs ----
        seg = jnp.exp(cumq[:, -1:, :] - cumq) * dtq          # [B, Q, H]
        bx = jnp.einsum("bjgn,bjgrp->bgrnp", bq.astype(jnp.float32),
                        (xq * seg[..., None]).reshape(bsz, q, g, rep, p)
                        .astype(jnp.float32))
        hnew = (hprev * jnp.exp(cumq[:, -1]).reshape(bsz, h)[:, :, None, None]
                + bx.reshape(bsz, h, n, p))
        y = (y_intra + y_inter).reshape(bsz, q, h, p)
        return hnew, y.astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    # checkpoint per chunk: the backward recomputes the [B, H, Q, Q]
    # decay/score tensors instead of storing them stacked over chunks
    hfin, ys = lax.scan(
        jax.checkpoint(chunk_step), h0,
        (xr.swapaxes(0, 1), dtr.swapaxes(0, 1), br.swapaxes(0, 1),
         cr.swapaxes(0, 1), cum.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(bsz, l, h, p)
    return y, hfin


def ssd_sequential(x, dt, a, b, c, h0=None):
    """Naive O(L) recurrence — test oracle for :func:`ssd_chunked`."""
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), jnp.float32)

    def step(hs, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,G,N], [B,G,N]
        da = jnp.exp(dtt * a.astype(dtt.dtype))              # [B, H]
        bth = jnp.repeat(bt, rep, axis=1)                    # [B, H, N]
        cth = jnp.repeat(ct, rep, axis=1)
        hs = (hs * da[..., None, None]
              + jnp.einsum("bhn,bhp->bhnp", bth.astype(jnp.float32),
                           (xt * dtt[..., None]).astype(jnp.float32)))
        y = jnp.einsum("bhn,bhnp->bhp", cth.astype(jnp.float32), hs)
        return hs, y

    hfin, ys = lax.scan(step, h0, (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                                   b.swapaxes(0, 1), c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hfin


def mamba2_forward(p, x, cfg: ModelConfig, conv_carry=None, h0=None):
    """Full Mamba2 mixer. ``x [B, L, D]`` -> ``[B, L, D]`` (+ final states)."""
    bsz, l, _ = x.shape
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    z, xbc, dt = _split_proj(cfg, linear(x, p["in_proj"]))
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_carry)
    xs = xbc[..., :cfg.d_inner].reshape(bsz, l, h, pdim)
    bmat = xbc[..., cfg.d_inner:cfg.d_inner + g * n].reshape(bsz, l, g, n)
    cmat = xbc[..., cfg.d_inner + g * n:].reshape(bsz, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, hfin = ssd_chunked(xs, dt.astype(x.dtype), a, bmat, cmat, cfg, h0)
    y = y + xs * p["d_skip"].astype(x.dtype)[:, None]
    y = y.reshape(bsz, l, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"]["scale"], cfg.rms_norm_eps)
    return linear(y, p["out_proj"]), conv_tail, hfin


def init_state(cfg: ModelConfig, batch: int, dtype):
    """Decode-time recurrent state: SSD state + conv tail."""
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                        cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
    }


def mamba2_decode(p, x, state, cfg: ModelConfig):
    """O(1) single-token step. ``x [B, 1, D]`` (chunk size degenerates to 1)."""
    import dataclasses
    cfg1 = dataclasses.replace(cfg, ssm_chunk=1)
    y, conv_tail, hfin = mamba2_forward(
        p, x, cfg1, conv_carry=state["conv"], h0=state["h"])
    return y, {"h": hfin, "conv": conv_tail}
