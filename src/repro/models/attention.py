"""GQA attention: blockwise (flash-style) train/prefill + cached decode.

Pure JAX, shard-agnostic: distribution comes entirely from the param specs
(heads on the ``model`` mesh axis when divisible) and the activation batch
sharding.  Long sequences never materialize the full score matrix — the
forward is a double ``lax.scan`` over (q-chunk, kv-chunk) with an online
softmax, and per-layer remat recomputes it in the backward pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import ParamBuilder, apply_rope, linear, rope_freqs

NEG_INF = -1e30


def gqa_init(pb: ParamBuilder, cfg: ModelConfig):
    hd = cfg.head_dim_
    sub = ParamBuilder(pb.key(), pb.dtype)
    sub.dense("q", cfg.d_model, cfg.eff_n_heads * hd, "embed", "heads",
              bias=cfg.qkv_bias)
    sub.dense("k", cfg.d_model, cfg.eff_n_kv_heads * hd, "embed", "kv",
              bias=cfg.qkv_bias)
    sub.dense("v", cfg.d_model, cfg.eff_n_kv_heads * hd, "embed", "kv",
              bias=cfg.qkv_bias)
    sub.dense("o", cfg.eff_n_heads * hd, cfg.d_model, "heads", "embed")
    p, s = sub.build()
    if cfg.head_pad_factor > 1:
        # zero the padded head block; zero o-proj ROWS make padded heads'
        # contribution exactly zero, so outputs match the unpadded model.
        import jax.numpy as jnp
        real_q, real_kv = cfg.n_heads * hd, cfg.n_kv_heads * hd
        for nm, real in (("q", real_q), ("k", real_kv), ("v", real_kv)):
            p[nm]["w"] = p[nm]["w"].at[:, real:].set(0.0)
            if "b" in p[nm]:
                p[nm]["b"] = p[nm]["b"].at[real:].set(0.0)
        p["o"]["w"] = p["o"]["w"].at[real_q:, :].set(0.0)
    pb.sub("attn", p, s)
    return pb


def _project_qkv(p, x, cfg: ModelConfig, positions):
    b, l, _ = x.shape
    hd = cfg.head_dim_
    q = linear(x, p["q"]).reshape(b, l, cfg.eff_n_heads, hd)
    k = linear(x, p["k"]).reshape(b, l, cfg.eff_n_kv_heads, hd)
    v = linear(x, p["v"]).reshape(b, l, cfg.eff_n_kv_heads, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_forward(p, x, cfg: ModelConfig, positions, *, window=None,
                q_chunk: int = 512, kv_chunk: int = 1024):
    """Full-sequence attention (train / prefill).

    ``x [B, L, D]``; ``positions [B, L]``; ``window`` overrides
    ``cfg.sliding_window`` for this layer (None = full).
    """
    b, l, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = blockwise_attention(
        q, k, v, positions,
        causal=cfg.causal, window=window,
        q_chunk=min(q_chunk, l), kv_chunk=min(kv_chunk, l))
    return linear(out.reshape(b, l, cfg.eff_n_heads * cfg.head_dim_), p["o"])


def blockwise_attention(q, k, v, positions=None, *, causal, window,
                        q_chunk, kv_chunk):
    """Flash-style chunked attention with online softmax.

    ``q [B, L, Hq, D]``, ``k/v [B, M, Hkv, D]``.  GQA is computed by
    reshaping q to ``[B, L, Hkv, G, D]`` — the kv tensors are never
    repeated/materialized per q-head.

    §Perf iteration A (see EXPERIMENTS.md): masks are derived from *chunk
    indices* — one shared ``[Cq, Ck]`` predicate instead of a per-batch-row
    ``[B, Cq, Ck]`` tensor — and work is structurally skipped:

    * sliding-window layers take the *banded* path: each q chunk touches
      only the ceil((W+Cq)/Ck)+1 kv chunks its window can reach (static);
    * causal full-attention skips strictly-acausal chunk pairs with a
      ``lax.cond`` (no compute, no memory traffic on the skipped branch).
    """
    b, l, hq, d = q.shape
    m, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    assert l % q_chunk == 0 and m % kv_chunk == 0, (l, q_chunk, m, kv_chunk)
    nq, nk = l // q_chunk, m // kv_chunk
    scale = d ** -0.5
    cq, ck = q_chunk, kv_chunk

    qr = (q.reshape(b, nq, cq, hkv, g, d) * scale).astype(q.dtype)
    kr = k.reshape(b, nk, ck, hkv, d)
    vr = v.reshape(b, nk, ck, hkv, d)

    banded = (causal and window is not None and window < m)
    if banded:
        # ---- banded path: static kv band per q chunk -------------------
        n_need = min((window - 1 + cq - 1) // ck + 2, nk)

        def q_step(_, qi):
            qb, iq = qi                       # [B, Cq, Hkv, G, D], scalar
            last = (iq * cq + cq - 1) // ck   # last kv chunk in band
            first = jnp.maximum(last - (n_need - 1), 0)
            kb = lax.dynamic_slice_in_dim(kr, first, n_need, axis=1)
            vb = lax.dynamic_slice_in_dim(vr, first, n_need, axis=1)
            kb = kb.reshape(b, n_need * ck, hkv, d)
            vb = vb.reshape(b, n_need * ck, hkv, d)
            rows = iq * cq + jnp.arange(cq)
            cols = first * ck + jnp.arange(n_need * ck)
            dp = rows[:, None] - cols[None, :]
            msk = (dp >= 0) & (dp < window)   # [Cq, n_need*Ck] shared
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(vb.dtype), vb)
            return None, o.astype(q.dtype)

        # checkpoint per q-chunk: backward recomputes the (Cq x band)
        # scores instead of carrying nq stacked score residuals (iter A3)
        _, out = lax.scan(jax.checkpoint(q_step), None,
                          (qr.swapaxes(0, 1), jnp.arange(nq)))
        return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, l, hq, d)

    # ---- general path: online softmax over kv chunks -------------------
    def q_step(_, qi):
        qb, iq = qi

        def kv_step(carry, ki):
            acc, mx, den = carry
            kb, vb, jk = ki               # [B, Ck, Hkv, D], ..., scalar

            def compute(c):
                acc, mx, den = c
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                               preferred_element_type=jnp.float32)
                dp = (iq * cq + jnp.arange(cq))[:, None] \
                    - (jk * ck + jnp.arange(ck))[None, :]
                msk = jnp.ones((cq, ck), bool)
                if causal:
                    msk = msk & (dp >= 0)
                if window is not None:
                    msk = msk & (dp < window)
                s = jnp.where(msk[None, None, None], s, NEG_INF)
                new_mx = jnp.maximum(mx, s.max(axis=-1))
                alpha = jnp.exp(mx - new_mx)
                ps = jnp.exp(s - new_mx[..., None])
                den2 = den * alpha + ps.sum(axis=-1)
                pv = jnp.einsum("bhgqk,bkhd->bqhgd", ps.astype(vb.dtype), vb)
                acc2 = acc * alpha.transpose(0, 3, 1, 2)[..., None] \
                    .astype(acc.dtype) + pv
                return acc2, new_mx, den2

            if causal:  # skip strictly-acausal chunk pairs entirely
                carry = lax.cond(jk * ck <= iq * cq + cq - 1, compute,
                                 lambda c: c, carry)
            else:
                carry = compute(carry)
            return carry, None

        acc0 = jnp.zeros(qb.shape, jnp.float32)
        mx0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        den0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        (acc, _, den), _ = lax.scan(
            kv_step, (acc0, mx0, den0),
            (kr.swapaxes(0, 1), vr.swapaxes(0, 1), jnp.arange(nk)))
        den = jnp.maximum(den, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, (acc / den).astype(q.dtype)

    _, out = lax.scan(jax.checkpoint(q_step), None,
                      (qr.swapaxes(0, 1), jnp.arange(nq)))
    # out: [nq, B, Cq, Hkv, G, D] -> [B, L, Hq, D]
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, l, hq, d)


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Per-layer KV cache [stacked over layers by the caller].

    Caches hold only the REAL kv heads: padded heads (head_pad_factor) are
    zero and attended only by padded q heads whose output is discarded —
    storing them would double decode cache traffic for nothing."""
    hd = cfg.head_dim_
    cache_len = max_len if cfg.sliding_window is None \
        else min(max_len, cfg.sliding_window)
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_decode(p, x, cache, cfg: ModelConfig, pos, *, window=None,
               full_cache_len=None):
    """Single-token decode.  ``x [B, 1, D]``, ``pos [B]`` absolute position;
    cache k/v ``[B, C, Hkv, D]`` is a ring buffer when ``window`` is set."""
    b = x.shape[0]
    hd = cfg.head_dim_
    # decode uses REAL heads only (cache excludes zero pad heads)
    q = linear(x, p["q"]).reshape(b, 1, cfg.eff_n_heads, hd)[:, :, :cfg.n_heads]
    k = linear(x, p["k"]).reshape(b, 1, cfg.eff_n_kv_heads,
                                  hd)[:, :, :cfg.n_kv_heads]
    v = linear(x, p["v"]).reshape(b, 1, cfg.eff_n_kv_heads,
                                  hd)[:, :, :cfg.n_kv_heads]
    cos, sin = rope_freqs(hd, cfg.rope_theta, pos[:, None])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    c = cache["k"].shape[1]
    slot = (pos % c).astype(jnp.int32)
    ck = _ring_write(cache["k"], k[:, 0], slot)
    cv = _ring_write(cache["v"], v[:, 0], slot)

    # positions currently held by each ring slot
    slot_ids = jnp.arange(c)[None, :]
    wrapped = pos[:, None] - ((slot[:, None] - slot_ids) % c)
    valid = (wrapped >= 0) & (wrapped <= pos[:, None])
    if window is not None:
        valid = valid & (wrapped > pos[:, None] - window)

    g = cfg.n_heads // cfg.n_kv_heads
    qr = q.reshape(b, cfg.n_kv_heads, g, hd) * hd ** -0.5
    s = jnp.einsum("bhgd,bchd->bhgc", qr, ck,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", w.astype(cv.dtype), cv)
    o_flat = o.reshape(b, 1, cfg.n_heads * hd)
    if cfg.head_pad_factor > 1:  # zero-fill pad-head rows for the o-proj
        o_flat = jnp.pad(o_flat, ((0, 0), (0, 0),
                                  (0, (cfg.eff_n_heads - cfg.n_heads) * hd)))
    y = linear(o_flat, p["o"])
    return y, {"k": ck, "v": cv}


def _ring_write(buf, val, slot):
    """``buf [B, C, ...]`` <- ``val [B, ...]`` at per-row ``slot [B]``.

    A per-row scatter (one slot written) — not a one-hot blend, which would
    rewrite the entire cache every step and double the decode memory term.
    """
    return buf.at[jnp.arange(buf.shape[0]), slot].set(val.astype(buf.dtype))
