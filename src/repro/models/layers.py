"""Shared neural building blocks (pure JAX, functional, param-dict based).

Parameters are plain nested dicts of ``jnp.ndarray``; every initializer
returns ``(params, specs)`` where ``specs`` mirrors the param tree with
*logical axis name* tuples — ``repro.sharding.rules`` maps those to mesh
``PartitionSpec``s, so distribution lives entirely outside the model code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (see repro/sharding/rules.py):
#   "embed"  — the d_model dim (FSDP-sharded over data)
#   "vocab"  — vocabulary (TP over model)
#   "heads"  — fused attention head dim (TP over model)
#   "kv"     — fused kv head dim
#   "ff"     — MLP hidden (TP over model)
#   "experts"— MoE expert dim (EP over model)
#   "lora"   — MLA latent rank
#   "inner"  — SSM inner width
#   None     — replicated


def dense_init(key, d_in, d_out, in_axis, out_axis, dtype, scale=None):
    scale = scale or (1.0 / np.sqrt(d_in))
    w = jax.random.normal(key, (d_in, d_out), dtype) * scale
    return w, (in_axis, out_axis)


class ParamBuilder:
    """Collects (param, spec) pairs into parallel pytrees."""

    def __init__(self, key, param_dtype):
        self._key = key
        self.dtype = param_dtype
        self.params: dict = {}
        self.specs: dict = {}

    def key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, name, d_in, d_out, in_axis="embed", out_axis=None,
              bias=False, scale=None):
        w, spec = dense_init(self.key(), d_in, d_out, in_axis, out_axis,
                             self.dtype, scale)
        self.params[name] = {"w": w}
        self.specs[name] = {"w": spec}
        if bias:
            self.params[name]["b"] = jnp.zeros((d_out,), self.dtype)
            self.specs[name]["b"] = (out_axis,)
        return self

    def norm(self, name, dim):
        self.params[name] = {"scale": jnp.ones((dim,), self.dtype)}
        self.specs[name] = {"scale": (None,)}
        return self

    def table(self, name, shape, axes, scale=0.02):
        self.params[name] = jax.random.normal(self.key(), shape, self.dtype) * scale
        self.specs[name] = axes
        return self

    def raw(self, name, value, axes):
        self.params[name] = value
        self.specs[name] = axes
        return self

    def sub(self, name, params, specs):
        self.params[name] = params
        self.specs[name] = specs
        return self

    def build(self):
        return self.params, self.specs


# ------------------------------------------------------------------ ops
def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def linear(x, p):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def swiglu(x, p):
    """SwiGLU MLP: ``w2(silu(w1 x) * w3 x)`` — Llama/Qwen/DeepSeek style."""
    gate = linear(x, p["w1"])
    up = linear(x, p["w3"])
    return linear(jax.nn.silu(gate) * up, p["w2"])


def swiglu_init(pb: ParamBuilder, name, d_model, d_ff):
    sub = ParamBuilder(pb.key(), pb.dtype)
    sub.dense("w1", d_model, d_ff, "embed", "ff")
    sub.dense("w3", d_model, d_ff, "embed", "ff")
    sub.dense("w2", d_ff, d_model, "ff", "embed")
    p, s = sub.build()
    pb.sub(name, p, s)
    return pb


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim, theta, positions):
    """``positions [...]`` -> (cos, sin) ``[..., head_dim/2]``."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Rotate pairs. ``x [..., L, H, D]``, cos/sin ``[..., L, D/2]``."""
    x1, x2 = jnp.split(x, 2, axis=-1)       # rotate-half convention
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------- losses
def softmax_xent(logits, labels, mask, vocab_size):
    """Mean masked cross-entropy; pads beyond ``vocab_size`` excluded."""
    logits = logits.astype(jnp.float32)
    pad = logits.shape[-1] - vocab_size
    if pad:
        neg = jnp.full((pad,), -1e30, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab_size,), jnp.float32), neg])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
