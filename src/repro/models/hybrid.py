"""Hymba-style hybrid block: parallel attention + Mamba2 heads.

Per arXiv:2411.13676, each layer runs an attention path and an SSM path on
the same normalized input *in parallel*; the outputs are per-channel
normalized and fused with learnable per-dim vectors (β).  Attention is
sliding-window except for designated global layers (first / middle / last),
which is what makes ``long_500k`` decodable: the KV memory is O(window) per
local layer while the SSM path carries unbounded context in O(1) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention, mamba2
from .config import ModelConfig
from .layers import ParamBuilder, rms_norm


def hybrid_init(pb: ParamBuilder, cfg: ModelConfig):
    attention.gqa_init(pb, cfg)
    mamba2.mamba2_init(pb, cfg)
    sub = ParamBuilder(pb.key(), pb.dtype)
    sub.norm("attn_out_norm", cfg.d_model)
    sub.norm("ssm_out_norm", cfg.d_model)
    sub.raw("beta_attn", jnp.full((cfg.d_model,), 0.5, pb.dtype), (None,))
    sub.raw("beta_ssm", jnp.full((cfg.d_model,), 0.5, pb.dtype), (None,))
    p, s = sub.build()
    pb.sub("fuse", p, s)
    return pb


def _fuse(pf, cfg: ModelConfig, a_out, s_out):
    a = rms_norm(a_out, pf["attn_out_norm"]["scale"], cfg.rms_norm_eps)
    s = rms_norm(s_out, pf["ssm_out_norm"]["scale"], cfg.rms_norm_eps)
    return (a * pf["beta_attn"].astype(a.dtype)
            + s * pf["beta_ssm"].astype(s.dtype))


def hybrid_forward(p, x, cfg: ModelConfig, positions, *, window=None,
                   q_chunk=512, kv_chunk=1024):
    a_out = attention.gqa_forward(p["attn"], x, cfg, positions, window=window,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk)
    s_out, _, _ = mamba2.mamba2_forward(p["ssm"], x, cfg)
    return _fuse(p["fuse"], cfg, a_out, s_out)


def hybrid_decode(p, x, cache, cfg: ModelConfig, pos, *, window=None):
    a_out, kv = attention.gqa_decode(p["attn"], x, cache["kv"], cfg, pos,
                                     window=window)
    s_out, ssm = mamba2.mamba2_decode(p["ssm"], x, cache["ssm"], cfg)
    return _fuse(p["fuse"], cfg, a_out, s_out), {"kv": kv, "ssm": ssm}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {"kv": attention.init_cache(cfg, batch, max_len, dtype),
            "ssm": mamba2.init_state(cfg, batch, dtype)}
