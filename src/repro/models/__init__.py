"""Assigned-architecture zoo: unified pure-JAX transformer/SSM/hybrid stack."""
from .config import ModelConfig  # noqa: F401
