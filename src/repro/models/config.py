"""Unified model configuration covering all 10 assigned architectures.

One dataclass; every block type (GQA attention, MLA, MoE FFN, Mamba2 SSD,
Hymba parallel-hybrid) is switched by fields.  Configs for the assigned
archs live in ``repro.configs.<id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    # -- trunk --
    num_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None          # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    vocab_pad_multiple: int = 128           # TPU-friendly embedding padding
    max_seq_len: int = 4096
    rope_theta: float = 1e4
    rms_norm_eps: float = 1e-6
    qkv_bias: bool = False                  # Qwen-style
    head_pad_factor: int = 1                # pad (q, kv) heads by this factor
    # (x-factor padding preserves the GQA grouping i//g exactly; padded o-proj
    #  rows are zero so outputs are bit-identical — §Perf iteration B1)
    tie_embeddings: bool = False
    causal: bool = True                     # False -> encoder (HuBERT)
    sliding_window: Optional[int] = None    # attention window (None = full)
    global_attn_layers: tuple = ()          # layers that override the window
    # -- attention flavor --
    attn_type: str = "gqa"                  # "gqa" | "mla" | "none"
    # MLA (DeepSeek-V2 / MiniCPM3)
    q_lora_rank: Optional[int] = None
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # -- FFN flavor --
    moe: bool = False
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 2
    moe_d_ff: int = 0                       # per-expert hidden dim
    first_k_dense: int = 0                  # DeepSeek: first k layers use dense FFN
    moe_capacity_factor: float = 1.25
    # -- SSM (Mamba2 SSD) --
    block_type: str = "attn"                # "attn" | "ssm" | "hybrid"
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1
    # -- multimodal stub frontends --
    modality: str = "text"                  # "text" | "audio" | "vision"
    frontend_dim: int = 0                   # stub feature dim (CLIP=1024 etc.)
    num_patches: int = 0                    # vision tokens per example
    # -- numerics / remat --
    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"                     # "none" | "full" (per-layer)

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def eff_n_heads(self) -> int:
        return self.n_heads * self.head_pad_factor

    @property
    def eff_n_kv_heads(self) -> int:
        return self.n_kv_heads * self.head_pad_factor

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad_multiple)

    @property
    def d_inner(self) -> int:               # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def qk_head_dim(self) -> int:            # MLA per-head q/k dim
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    def num_params(self) -> int:
        """Analytic parameter count (excluding stub frontends)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d                                  # embed
        if not self.tie_embeddings:
            n += d * v                             # lm head
        per_layer = 2 * d                          # norms
        if self.block_type in ("attn", "hybrid"):
            per_layer += self._attn_params()
        if self.block_type in ("ssm", "hybrid"):
            per_layer += self._ssm_params()
        if self.block_type != "ssm":
            moe_layers = max(self.num_layers - self.first_k_dense, 0) if self.moe else 0
            dense_layers = self.num_layers - moe_layers
            if self.moe:
                per_moe = (self.n_routed_experts + self.n_shared_experts) \
                    * 3 * d * self.moe_d_ff + d * self.n_routed_experts
                n += moe_layers * per_moe
                n += dense_layers * 3 * d * self.d_ff
                per_layer_ffn = 0
            else:
                per_layer_ffn = 3 * d * self.d_ff
            per_layer += per_layer_ffn
        n += self.num_layers * per_layer + 2 * d
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_type == "mla":
            n = 0
            if self.q_lora_rank:
                n += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * self.qk_head_dim
            else:
                n += d * self.n_heads * self.qk_head_dim
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            n += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            n += self.n_heads * self.v_head_dim * d
            return n
        hd = self.head_dim_
        return (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        conv_dim = di + 2 * self.ssm_groups * self.ssm_state
        return (d * (2 * di + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads)
                + conv_dim * self.ssm_conv + 3 * self.ssm_heads + di * d)
