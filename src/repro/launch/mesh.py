"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state) returning the target topology:

* single-pod: ``(16, 16)`` over ``("data", "model")``  — 256 chips,
* multi-pod:  ``(2, 16, 16)`` over ``("pod", "data", "model")`` — 512 chips.

Smaller test meshes come from :func:`make_mesh`.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API; older jax has implicit-auto only
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
