"""End-to-end training driver (CPU-runnable at smoke scale).

Wires every substrate together: synthetic token pipeline -> model ->
AdamW train step (jitted, mesh-sharded when devices allow) -> async
checkpointing with crash-safe resume.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs import registry
from repro.data import tokens as token_data
from repro.launch.mesh import dp_axes as mesh_dp, make_mesh
from repro.models.model import build_model
from repro.models.transformer import Parallel
from repro.sharding.rules import params_pspecs
from repro.sharding.specs import batch_spec
from repro.train.optimizer import AdamWConfig, adamw
from repro.train.train_step import TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke if args.smoke else registry.get_arch)(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params~{cfg.num_params()/1e6:.1f}M "
          f"block={cfg.block_type} moe={cfg.moe}")

    # ---- mesh: use whatever devices exist (1 on CPU unless XLA_FLAGS) ----
    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else None
    par = Parallel(mesh=mesh) if mesh else Parallel.local()

    params, specs = model.init(jax.random.PRNGKey(args.seed))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    opt_init, _ = adamw(opt_cfg)
    state = TrainState(params, opt_init(params), jnp.zeros((), jnp.int32))

    manager = None
    if args.ckpt_dir:
        manager = ckpt_lib.CheckpointManager(args.ckpt_dir,
                                             logical_specs=specs)
        if args.resume and ckpt_lib.latest_step(args.ckpt_dir) is not None:
            state, step0 = ckpt_lib.restore(args.ckpt_dir, state)
            print(f"resumed from step {step0}")

    step_fn = make_train_step(model, par, opt_cfg,
                              microbatches=args.microbatches)
    if mesh:
        p_ps = params_pspecs(specs, params, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        st_sh = TrainState(
            jax.tree.map(lambda s: NamedSharding(mesh, s), p_ps,
                         is_leaf=lambda x: isinstance(x, P)),
            type(state.opt_state)(
                m=jax.tree.map(lambda s: NamedSharding(mesh, s), p_ps,
                               is_leaf=lambda x: isinstance(x, P)),
                v=jax.tree.map(lambda s: NamedSharding(mesh, s), p_ps,
                               is_leaf=lambda x: isinstance(x, P)),
                count=NamedSharding(mesh, P())),
            NamedSharding(mesh, P()))
        step_fn = jax.jit(step_fn, in_shardings=(st_sh, None),
                          out_shardings=(st_sh, None), donate_argnums=(0,))
        state = jax.device_put(state, st_sh)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # ---- data ----
    rng = np.random.default_rng(args.seed)
    stream = token_data.TokenStream(cfg.vocab_size, seed=args.seed)

    def next_batch():
        if cfg.modality == "audio":
            return token_data.audio_batch(rng, args.batch, args.seq,
                                          cfg.d_model, cfg.vocab_size)
        if cfg.modality == "vision":
            return token_data.vision_batch(rng, args.batch, args.seq,
                                           cfg.num_patches, cfg.frontend_dim,
                                           cfg.vocab_size, stream)
        return stream.batch(args.batch, args.seq)

    start = int(state.step)
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        state, metrics = step_fn(state, next_batch())
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            tput = args.log_every * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step + 1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tput:,.0f}")
            t0 = time.time()
        if manager and (step + 1) % args.ckpt_every == 0:
            manager.save_async(step + 1, state)
    if manager:
        manager.save_async(int(state.step), state)
        manager.wait()
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first-10 {np.mean(losses[:10]):.4f})")
    return np.mean(losses[-10:])


if __name__ == "__main__":
    main()
