"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the full train/prefill/decode step is jit-lowered with production
shardings against ShapeDtypeStruct inputs (no allocation), compiled for the
512-way (multi-pod) / 256-way (single-pod) SPMD mesh, and the compiled
artifact's memory/cost/collective statistics are recorded for
EXPERIMENTS.md §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both \
        [--arch qwen2-7b] [--shape train_4k] --out results/dryrun.json
"""
# The first two statements MUST precede any jax import: jax locks the device
# count at first initialization.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import numpy as np   # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.launch.mesh import dp_axes as mesh_dp, make_production_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.models.transformer import Parallel, plan_segments  # noqa: E402
from repro.sharding.rules import params_pspecs  # noqa: E402
from repro.sharding.specs import batch_spec, cache_spec  # noqa: E402
from repro.train.optimizer import AdamWConfig  # noqa: E402
from repro.train.train_step import TrainState, make_train_step  # noqa: E402
from repro.train.optimizer import adamw  # noqa: E402

# ---------------------------------------------------------------- helpers
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_ARR_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16"
                     r"|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _arr_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result-buffer bytes of every collective op in optimized HLO."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", ls)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        kind = m.group(2)
        out[kind]["bytes"] += _arr_bytes(m.group(1))
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def abstract_params(model):
    """(param ShapeDtypeStructs, logical specs) without allocation."""
    cap = {}

    def init_only(key):
        p, s = model.init(key)
        cap["specs"] = s
        return p

    shapes = jax.eval_shape(init_only, jax.random.PRNGKey(0))
    return shapes, cap["specs"]


def _sds(tree, shardings):
    """Attach shardings to a ShapeDtypeStruct tree (for .lower)."""
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings)


def _named(tree_pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def serve_cache_pspecs(cfg, caches_sds, mesh):
    segs = plan_segments(cfg)

    def leaf_spec(shape):
        sp = cache_spec(shape, mesh)
        if cfg.attn_type == "mla" and len(shape) == 3:
            # §Perf C2: the MLA latent dims (kv_lora, rope) are CONTRACTED
            # against every decode step's query — model-sharding them makes
            # XLA all-gather the whole compressed cache per layer (observed:
            # 536 MB/layer).  Shard (batch over dp) x (SEQ over model):
            # attention contracts r locally per seq shard and the softmax /
            # context psums are tiny [b, h]-vectors, while the cache stays
            # 256-way sharded.
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            bdim = sp[0] if shape[0] % max(
                1, int(np.prod([mesh.shape[a] for a in dp]))) == 0 else None
            sdim = "model" if shape[1] % mesh.shape.get("model", 1) == 0 \
                else None
            sp = P(bdim, sdim, None)
        return sp

    out = []
    for seg, tree in zip(segs, caches_sds["segments"]):
        if seg.num_layers > 1:
            out.append(jax.tree.map(
                lambda x: P(None, *tuple(leaf_spec(x.shape[1:]))), tree))
        else:
            out.append(jax.tree.map(lambda x: leaf_spec(x.shape), tree))
    return {"segments": out}


def logits_spec(shape, mesh):
    dims = list(batch_spec(shape, mesh))
    if shape[-1] % mesh.shape.get("model", 1) == 0 and "model" in mesh.shape:
        dims[-1] = "model"
    return P(*dims)


# ------------------------------------------------------------ cell builder
def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    cfg = registry.get_arch(arch)
    shape = registry.SHAPES[shape_name]
    par = Parallel(mesh=mesh, dp_axes=mesh_dp(mesh), tp_axis="model")
    model = build_model(cfg)
    p_sds, p_logical = abstract_params(model)
    p_pspecs = params_pspecs(p_logical, p_sds, mesh)
    p_shard = _named(p_pspecs, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(model, par, opt_cfg)
        opt_init, _ = adamw(opt_cfg)
        state_sds = jax.eval_shape(
            lambda p: TrainState(p, opt_init(p), jnp.zeros((), jnp.int32)),
            p_sds)
        f32_shard = jax.tree.map(lambda s: s, p_shard)  # moments mirror params
        state_shard = TrainState(
            params=p_shard,
            opt_state=type(state_sds.opt_state)(
                m=f32_shard, v=f32_shard,
                count=NamedSharding(mesh, P())),
            step=NamedSharding(mesh, P()))
        batch_sds = registry.input_specs(arch, shape_name)["batch"]
        batch_shard = jax.tree.map(
            lambda x: NamedSharding(mesh, batch_spec(x.shape, mesh)),
            batch_sds)
        metrics_shard = {"loss": NamedSharding(mesh, P()),
                         "grad_norm": NamedSharding(mesh, P()),
                         "lr": NamedSharding(mesh, P())}
        args = (_sds(state_sds, state_shard), _sds(batch_sds, batch_shard))
        return (step, args, (state_shard, batch_shard),
                (state_shard, metrics_shard), (0,))

    if shape.kind == "prefill":
        b, l = shape.global_batch, shape.seq_len
        batch_sds = registry.input_specs(arch, shape_name)["batch"]
        batch_shard = jax.tree.map(
            lambda x: NamedSharding(mesh, batch_spec(x.shape, mesh)),
            batch_sds)
        if not cfg.causal:  # encoder: "prefill" = full encode, no cache
            fn = lambda p, bt: model.forward(p, bt, par)
            out_shard = NamedSharding(
                mesh, logits_spec((b, l, cfg.padded_vocab), mesh))
            args = (_sds(p_sds, p_shard), _sds(batch_sds, batch_shard))
            return fn, args, (p_shard, batch_shard), out_shard, ()
        fn = lambda p, bt: model.prefill(p, bt, par, l)
        caches_sds = jax.eval_shape(
            lambda: model.init_caches({"trunk": None}, b, l))
        cache_shard = _named(serve_cache_pspecs(cfg, caches_sds, mesh), mesh)
        lg_shard = NamedSharding(mesh,
                                 logits_spec((b, 1, cfg.padded_vocab), mesh))
        args = (_sds(p_sds, p_shard), _sds(batch_sds, batch_shard))
        return (fn, args, (p_shard, batch_shard), (lg_shard, cache_shard),
                ())

    # decode: the full serve step (sample next token, update cache)
    b, l = shape.global_batch, shape.seq_len
    spec = registry.input_specs(arch, shape_name)
    caches_sds = spec["caches"]
    cache_shard = _named(serve_cache_pspecs(cfg, caches_sds, mesh), mesh)
    tok_shard = NamedSharding(mesh, batch_spec((b, 1), mesh))
    pos_shard = NamedSharding(mesh, batch_spec((b,), mesh))

    def serve_step(p, token, pos, caches):
        logits, caches = model.decode(p, token, pos, caches, par)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], pos + 1, caches

    args = (_sds(p_sds, p_shard), _sds(spec["token"], tok_shard),
            _sds(spec["pos"], pos_shard), _sds(caches_sds, cache_shard))
    return (serve_step, args, (p_shard, tok_shard, pos_shard, cache_shard),
            (tok_shard, pos_shard, cache_shard), (3,))


# -------------------------------------------------------------------- run
def run_cell(arch: str, shape_name: str, mesh, mesh_name: str) -> dict:
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(arch, shape_name, mesh)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)
    cost = compiled.cost_analysis() or {}
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed")})
    hlo_text = compiled.as_text()
    coll = collective_stats(hlo_text)
    # loop-aware accounting: cost_analysis counts while bodies ONCE — a
    # 60-layer scan would be ~60x undercounted (see hlo_analysis docstring)
    from repro.launch.hlo_analysis import analyze_text
    deep = analyze_text(hlo_text)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "devices": int(len(mesh.devices.flat)),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_raw_costan": float(cost.get("flops", -1.0)),
        "bytes_raw_costan": float(cost.get("bytes accessed", -1.0)),
        "flops": deep["flops"],
        "hbm_bytes": deep["hbm_bytes"],
        "collectives": deep["collectives"],
        "collective_bytes": deep["collective_bytes"],
        "collectives_unrolled_raw": coll,
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        rec[attr] = int(getattr(mem, attr, -1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        # always keep prior cells; --force only forces RE-RUNNING matches
        with open(args.out) as fh:
            results = json.load(fh)

    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    cells = [(a, s) for a, s, r in registry.cells()]
    if args.arch:
        aid = registry.ALIASES.get(args.arch, args.arch)
        cells = [(a, s) for a, s in cells if a == aid]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]

    n_fail = 0
    for mesh_name, mesh in meshes:
        for arch, shape_name in cells:
            key = f"{arch}/{shape_name}/{mesh_name}"
            if key in results and results[key].get("ok") and not args.force:
                print(f"[skip] {key} (cached)")
                continue
            print(f"[cell] {key} ...", flush=True)
            try:
                rec = run_cell(arch, shape_name, mesh, mesh_name)
                rec["ok"] = True
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            results[key] = rec
            with open(args.out, "w") as fh:
                json.dump(results, fh, indent=1, sort_keys=True)
            if rec["ok"]:
                print(f"[ok]   {key}: compile={rec['compile_s']}s "
                      f"flops={rec['flops']:.3g} "
                      f"coll={rec['collective_bytes']:.3g}B")
    print(f"done: {len(results)} cells recorded, {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
