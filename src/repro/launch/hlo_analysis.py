"""Optimized-HLO analyzer: loop-aware FLOPs / HBM bytes / collective bytes.

``compiled.cost_analysis()`` counts each while-loop *body once* — a 60-layer
scanned transformer would be undercounted ~60x.  This module parses the
optimized (post-SPMD) HLO text and recursively multiplies through
``known_trip_count`` backend configs, giving per-device:

* ``flops``       — 2 * numel(out) * contracted-dim product, per ``dot``
                    (+ convolutions), through fusions/whiles/calls;
* ``hbm_bytes``   — fusion-boundary traffic: every non-trivial top-level
                    op's operand + result buffer bytes (fusion internals
                    never touch HBM — the standard roofline convention);
* ``collectives`` — per-kind wire bytes x trip counts, with group sizes,
                    so the roofline's collective term is exact for scans.

The parser targets the textual HLO emitted by jax 0.8 / XLA CPU+SPMD; it is
validated against analytic 6*N*D model FLOPs in tests.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "u4": 1, "s4": 1}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred"
    r"|c64|c128|u4|s4|token)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_TRUE_RE = re.compile(r"true_computation=%?([\w.\-]+)")
_FALSE_RE = re.compile(r"false_computation=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
}
COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_bytes: int
    result_numel: int
    operands: list
    line: str


def _parse_shapes(segment: str):
    """All (dtype, numel) in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt == "token":
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(segment: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _parse_shapes(segment))


def _numel_of(segment: str) -> int:
    return sum(n for _, n in _parse_shapes(segment))


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Op]] = {}
        self.shape_of: dict[str, str] = {}
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            mc = _COMP_RE.match(line)
            if mc and ("->" in line or line.startswith("ENTRY")):
                cur = mc.group(1)
                self.computations[cur] = []
                continue
            if line.strip() == "}":
                continue
            mo = _OP_RE.match(line)
            if mo and cur is not None:
                name, rtype, kind, rest = mo.groups()
                operands = re.findall(r"%([\w.\-]+)", rest.split("),")[0]
                                      if ")," in rest else rest)
                op = Op(name=name, kind=kind,
                        result_bytes=_bytes_of(rtype),
                        result_numel=_numel_of(rtype),
                        operands=operands, line=line.strip())
                self.computations[cur].append(op)
                self.shape_of[name] = rtype

    # ------------------------------------------------------------- flops
    def _dot_flops(self, op: Op) -> float:
        # contracted sizes from the lhs operand's shape
        m = _CONTRACT_RE.search(op.line)
        if not m or not op.operands:
            return 2.0 * op.result_numel  # degenerate
        lhs = self.shape_of.get(op.operands[0], "")
        sh = _SHAPE_RE.search(lhs)
        if not sh:
            return 2.0 * op.result_numel
        dims = [int(d) for d in sh.group(2).split(",") if d]
        k = 1
        for ci in (int(c) for c in m.group(1).split(",") if c):
            if ci < len(dims):
                k *= dims[ci]
        return 2.0 * op.result_numel * k

    def analyze(self, comp: str | None = None, _memo=None) -> dict:
        """Returns {'flops', 'hbm_bytes', 'collectives': {kind: {...}}}."""
        if comp is None:
            comp = next((c for c in self.computations if "main" in c),
                        list(self.computations)[-1])
        if _memo is None:
            _memo = {}
        if comp in _memo:
            return _memo[comp]
        flops = 0.0
        eltwise = 0.0
        hbm = 0.0
        coll = defaultdict(lambda: {"bytes": 0.0, "count": 0.0,
                                    "group": set()})
        nested_of = {}  # op types whose called comps are HBM-internal
        for op in self.computations.get(comp, []):
            kind = op.kind
            base_kind = kind.replace("-start", "").replace("-done", "")
            if kind.endswith("-done"):
                continue
            if base_kind in COLLECTIVE_KINDS:
                c = coll[base_kind]
                c["bytes"] += op.result_bytes
                c["count"] += 1
                g = _GROUPS_BRACE_RE.search(op.line)
                if g:
                    c["group"].add(len(g.group(1).split(",")))
                else:
                    gi = _GROUPS_IOTA_RE.search(op.line)
                    if gi:
                        c["group"].add(int(gi.group(2)))
                hbm += op.result_bytes  # in+out traffic approx by result
                continue
            if kind == "dot":
                flops += self._dot_flops(op)
                hbm += op.result_bytes + sum(
                    _bytes_of(self.shape_of.get(o, "")) for o in op.operands)
                continue
            if kind == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    sub = self.analyze(m.group(1), _memo)
                    flops += sub["flops"]  # dots can hide inside fusions
                    eltwise += sub["eltwise_flops"]
                    for k2, v2 in sub["collectives"].items():
                        coll[k2]["bytes"] += v2["bytes"]
                        coll[k2]["count"] += v2["count"]
                        coll[k2]["group"] |= set(v2["group"])
                eltwise += op.result_numel
                hbm += op.result_bytes + sum(
                    _bytes_of(self.shape_of.get(o, "")) for o in op.operands)
                continue
            if kind == "while":
                trips = 1
                mt = _TRIP_RE.search(op.line)
                if mt:
                    trips = int(mt.group(1))
                mb = _BODY_RE.search(op.line)
                if mb:
                    sub = self.analyze(mb.group(1), _memo)
                    flops += trips * sub["flops"]
                    eltwise += trips * sub["eltwise_flops"]
                    hbm += trips * sub["hbm_bytes"]
                    for k2, v2 in sub["collectives"].items():
                        coll[k2]["bytes"] += trips * v2["bytes"]
                        coll[k2]["count"] += trips * v2["count"]
                        coll[k2]["group"] |= set(v2["group"])
                continue
            if kind == "conditional":
                # branches execute data-dependently; charge the MEAN across
                # branches (for the causal chunk-skip pattern this matches
                # the ~triangular executed fraction).
                names = []
                mt, mf = _TRUE_RE.search(op.line), _FALSE_RE.search(op.line)
                if mt and mf:
                    names = [mt.group(1), mf.group(1)]
                else:
                    mb = _BRANCHES_RE.search(op.line)
                    if mb:
                        names = re.findall(r"%?([\w.\-]+)", mb.group(1))
                subs = [self.analyze(n, _memo) for n in names
                        if n in self.computations]
                if subs:
                    k_ = len(subs)
                    flops += sum(s_["flops"] for s_ in subs) / k_
                    eltwise += sum(s_["eltwise_flops"] for s_ in subs) / k_
                    hbm += sum(s_["hbm_bytes"] for s_ in subs) / k_
                    for s_ in subs:
                        for k2, v2 in s_["collectives"].items():
                            coll[k2]["bytes"] += v2["bytes"] / k_
                            coll[k2]["count"] += v2["count"] / k_
                            coll[k2]["group"] |= set(v2["group"])
                hbm += op.result_bytes
                continue
            if kind in ("call", "custom-call", "map",
                        "reduce", "sort", "scatter", "select-and-scatter"):
                called = None
                for attr_re in (_TO_APPLY_RE, _CALLS_RE):
                    m = attr_re.search(op.line)
                    if m and m.group(1) in self.computations:
                        called = m.group(1)
                        sub = self.analyze(called, _memo)
                        flops += sub["flops"]
                        eltwise += sub["eltwise_flops"]
                        for k2, v2 in sub["collectives"].items():
                            coll[k2]["bytes"] += v2["bytes"]
                            coll[k2]["count"] += v2["count"]
                            coll[k2]["group"] |= set(v2["group"])
                        break
                if kind == "call" and called is not None:
                    # outlined top-level computation (XLA:CPU wraps
                    # parallel fusions this way): its ops sit at the
                    # fusion boundary, so its traffic IS this call's
                    # traffic — and already includes the root's result.
                    hbm += sub["hbm_bytes"]
                    continue
                if kind not in SKIP_BYTES_OPS:
                    hbm += op.result_bytes
                continue
            if kind == "convolution":
                # flops ~ 2 * out_numel * (in_ch * kernel_spatial): derive
                # from operand 1 (kernel) numel / out_channels — good enough
                # for the depthwise convs used here.
                ker = self.shape_of.get(op.operands[1], "") \
                    if len(op.operands) > 1 else ""
                flops += 2.0 * op.result_numel * max(_numel_of(ker), 1) \
                    / max(op.result_numel, 1)
                hbm += op.result_bytes
                continue
            if kind in SKIP_BYTES_OPS:
                continue
            if kind not in ("copy", "dynamic-slice", "dynamic-update-slice",
                            "reshape", "transpose", "broadcast", "convert",
                            "slice", "concatenate", "pad", "gather",
                            "scatter", "reverse"):
                eltwise += op.result_numel  # 1 flop/elem estimate
            hbm += op.result_bytes  # copies, dynamic-slice/update, etc.
        res = {"flops": flops, "eltwise_flops": eltwise, "hbm_bytes": hbm,
               "collectives": {k: {"bytes": v["bytes"], "count": v["count"],
                                   "group": sorted(v["group"])}
                               for k, v in coll.items()}}
        _memo[comp] = res
        return res


def analyze_text(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    res = mod.analyze()
    res["collective_bytes"] = sum(v["bytes"]
                                  for v in res["collectives"].values())
    return res
