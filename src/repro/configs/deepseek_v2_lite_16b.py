"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: 27L d=2048 16H MLA
(no q_lora, kv_lora 512, nope 128 + rope 64, v 128); MoE: 64 routed top-6
+ 2 shared, per-expert ff 1408, first layer dense (ff 10944); vocab 102400."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b", num_layers=27, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=10944, vocab_size=102400, attn_type="mla",
    q_lora_rank=None, kv_lora_rank=512, qk_nope_head_dim=128,
    qk_rope_head_dim=64, v_head_dim=128,
    moe=True, n_routed_experts=64, n_shared_experts=2, moe_top_k=6,
    moe_d_ff=1408, first_k_dense=1, rope_theta=1e4, max_seq_len=32768)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke", num_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=192, vocab_size=512, attn_type="mla",
    q_lora_rank=None, kv_lora_rank=32, qk_nope_head_dim=16,
    qk_rope_head_dim=8, v_head_dim=16, moe=True, n_routed_experts=8,
    n_shared_experts=2, moe_top_k=2, moe_d_ff=48, first_k_dense=1,
    rope_theta=1e4, max_seq_len=256, dtype="float32")
