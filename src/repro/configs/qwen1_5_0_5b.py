"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]: 24L d=1024 16H (kv 16) ff=2816,
vocab 151936, QKV bias, tied embeddings."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-0.5b", num_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=2816, vocab_size=151936, qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6, max_seq_len=32768)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke", num_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=176, vocab_size=512, qkv_bias=True,
    tie_embeddings=True, rope_theta=1e6, max_seq_len=256, dtype="float32")
