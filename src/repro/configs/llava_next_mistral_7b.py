"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
32L d=4096 32H (GQA kv 8) ff=14336 vocab 32000.  CLIP vision tower is a
STUB (input_specs provides 1024-d patch features); the 2-layer GELU
mm-projector is real.  anyres tiling -> prefill uses 5x576 patch tokens.

Note: the llava-1.6 Mistral backbone runs full (non-windowed) attention;
long_500k is therefore skipped for this arch."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b", num_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=32000,
    modality="vision", frontend_dim=1024, num_patches=576,
    rope_theta=1e6, max_seq_len=32768)

SMOKE = ModelConfig(
    name="llava-next-mistral-smoke", num_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, modality="vision",
    frontend_dim=32, num_patches=8, rope_theta=1e6, max_seq_len=256,
    dtype="float32")
