"""Qwen2-7B [arXiv:2407.10671]: 28L d=3584 28H (GQA kv 4) ff=18944,
vocab 152064, QKV bias."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-7b", num_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    head_dim=128, d_ff=18944, vocab_size=152064, qkv_bias=True,
    rope_theta=1e6, max_seq_len=32768)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=512, qkv_bias=True, rope_theta=1e6,
    max_seq_len=256, dtype="float32")
