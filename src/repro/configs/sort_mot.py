"""The paper's own workload config: SORT over MOT15-shaped streams.

`PROD` sizes the tracking service for a production mesh: the stream axis is
the population axis (sharded over pod x data), slot capacity covers paper
Table I's max of 13 simultaneous objects with headroom."""
import dataclasses

from repro.core.sort import SortConfig


@dataclasses.dataclass(frozen=True)
class SortServiceConfig:
    sort: SortConfig
    streams_per_chip: int = 2048     # lane batch per device
    frames_per_segment: int = 512    # scan length per device step
    # shards of the scheduler's lane budget over the 1-D ("lanes",) device
    # mesh (DESIGN.md §7); the total lane budget is
    # streams_per_chip * lane_shards and must divide evenly.  1 = single
    # device, no mesh.
    lane_shards: int = 1

    @property
    def num_lanes(self) -> int:
        return self.streams_per_chip * self.lane_shards


FULL = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian"))

# Lane-resident fused serving path, paper-exact: one kernel dispatch per
# frame with the Hungarian JV solve as its jitted lane-batched feed stage
# (DESIGN.md §6).  Swap assoc="greedy" to trade optimality for the cheaper
# in-kernel matcher (benchmarks/association_ablation.py quantifies both).
FUSED = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True))

# Device-sharded serving (DESIGN.md §7): the FUSED engine with its lane
# budget spread over an 8-device ("lanes",) mesh — one fused dispatch per
# device per frame, zero collectives, bit-identical to single-device.
# Build the mesh with repro.sharding.lane_mesh(lane_shards) and pass it as
# StreamScheduler(mesh=...).
SHARDED = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True),
    lane_shards=8)

SMOKE = SortServiceConfig(
    sort=SortConfig(max_trackers=8, max_detections=8, assoc="hungarian"),
    streams_per_chip=8, frames_per_segment=16)
