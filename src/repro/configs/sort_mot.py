"""The paper's own workload config: SORT over MOT15-shaped streams.

`PROD` sizes the tracking service for a production mesh: the stream axis is
the population axis (sharded over pod x data), slot capacity covers paper
Table I's max of 13 simultaneous objects with headroom."""
import dataclasses

from repro.core.sort import SortConfig


@dataclasses.dataclass(frozen=True)
class SortServiceConfig:
    sort: SortConfig
    streams_per_chip: int = 2048     # lane batch per device
    frames_per_segment: int = 512    # scan length per device step


FULL = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian"))

# Lane-resident fused serving path, paper-exact: one kernel dispatch per
# frame with the Hungarian JV solve as its jitted lane-batched feed stage
# (DESIGN.md §6).  Swap assoc="greedy" to trade optimality for the cheaper
# in-kernel matcher (benchmarks/association_ablation.py quantifies both).
FUSED = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True))

SMOKE = SortServiceConfig(
    sort=SortConfig(max_trackers=8, max_detections=8, assoc="hungarian"),
    streams_per_chip=8, frames_per_segment=16)
