"""The paper's own workload config: SORT over MOT15-shaped streams.

`PROD` sizes the tracking service for a production mesh: the stream axis is
the population axis (sharded over pod x data), slot capacity covers paper
Table I's max of 13 simultaneous objects with headroom."""
import dataclasses

from repro.core.sort import SortConfig


@dataclasses.dataclass(frozen=True)
class SortServiceConfig:
    sort: SortConfig
    streams_per_chip: int = 2048     # lane batch per device
    frames_per_segment: int = 512    # scan length per device step


FULL = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3))

SMOKE = SortServiceConfig(
    sort=SortConfig(max_trackers=8, max_detections=8),
    streams_per_chip=8, frames_per_segment=16)
