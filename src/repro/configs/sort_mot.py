"""The paper's own workload config: SORT over MOT15-shaped streams.

`PROD` sizes the tracking service for a production mesh: the stream axis is
the population axis (sharded over pod x data), slot capacity covers paper
Table I's max of 13 simultaneous objects with headroom."""
import dataclasses

from repro.core import cost
from repro.core.sort import SortConfig


@dataclasses.dataclass(frozen=True)
class SortServiceConfig:
    sort: SortConfig
    streams_per_chip: int = 2048     # lane batch per device
    frames_per_segment: int = 512    # scan length per device step
    # shards of the scheduler's lane budget over the 1-D ("lanes",) device
    # mesh (DESIGN.md §7); the total lane budget is
    # streams_per_chip * lane_shards and must divide evenly.  1 = single
    # device, no mesh.
    lane_shards: int = 1
    # elastic lane budget bounds (DESIGN.md §8): when set, the scheduler
    # autoscales over the pre-compiled power-of-two ladder
    # [min_lanes .. max_lanes] from queue depth and utilization
    # (StreamScheduler(min_lanes=, max_lanes=)); max_lanes must be
    # min_lanes * 2**k, and in mesh mode every ladder width must divide
    # over lane_shards.  None = fixed budget of num_lanes.
    min_lanes: int | None = None
    max_lanes: int | None = None
    # service front-end knobs (DESIGN.md §11) — consumed by
    # repro.serve.TrackingService when this config backs a served
    # deployment.  max_pending/per_client_pending bound the admission
    # queue (submissions beyond them are shed with a Retry-After hint);
    # rate/burst parameterize the per-client token bucket (None = no rate
    # limit); ckpt_every is the chunk-boundary checkpoint cadence (0 = no
    # checkpointing, i.e. no crash recovery).
    max_pending: int = 4096
    per_client_pending: int = 64
    rate: float | None = None
    burst: float | None = None
    ckpt_every: int = 0

    @property
    def num_lanes(self) -> int:
        return self.streams_per_chip * self.lane_shards


FULL = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian"))

# Lane-resident fused serving path, paper-exact: one kernel dispatch per
# frame with the Hungarian JV solve as its jitted lane-batched feed stage
# (DESIGN.md §6).  Swap assoc="greedy" to trade optimality for the cheaper
# in-kernel matcher (benchmarks/association_ablation.py quantifies both).
FUSED = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True))

# Device-sharded serving (DESIGN.md §7): the FUSED engine with its lane
# budget spread over an 8-device ("lanes",) mesh — one fused dispatch per
# device per frame, zero collectives, bit-identical to single-device.
# Build the mesh with repro.sharding.lane_mesh(lane_shards) and pass it as
# StreamScheduler(mesh=...).
SHARDED = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True),
    lane_shards=8)

# Elastic lane serving (DESIGN.md §8): the FUSED engine with an
# autoscaling budget — bursty traffic grows the ladder 256 -> 512 -> 1024
# -> 2048 the moment demand exceeds the width, and idle phases shrink it
# back once the evacuating lanes drain.  Every width is pre-compiled at
# construction, so a resize never recompiles; outputs stay bit-identical
# to a fixed max_lanes run (tests/test_autoscale.py).
ELASTIC = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True),
    min_lanes=256, max_lanes=2048)

# Class-partitioned multi-class serving (DESIGN.md §10): the FUSED engine
# with a 3-way class partition and an appearance-embedding cost term.
# Cross-class det/track pairs are masked infeasible, so the one
# lane-batched assignment solves the block-diagonal per-class problem —
# same dispatch count, same zero-collective sharding as FUSED.  Steps
# consume det_class/det_embed operands
# (StreamScheduler.submit(..., det_class=, det_embed=)).
MULTICLASS = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True, cost=cost.iou_embed(embed_dim=8),
                    num_classes=3))

# Crash-exact resumable serving (DESIGN.md §11): the FUSED engine behind
# repro.serve.TrackingService — bounded async admission with explicit
# Retry-After shedding, per-client token-bucket rate limiting, a circuit
# breaker over device dispatch, and a full-state checkpoint at every
# chunk boundary so a SIGKILL'd server resumes bit-exactly.  The engine
# is deliberately a non-megakernel path: checkpoints are topology-
# neutral, so this server may resume a megakernel run's checkpoint (and
# vice versa).
SERVICE = SortServiceConfig(
    sort=SortConfig(max_trackers=16, max_detections=16, iou_threshold=0.3,
                    max_age=1, min_hits=3, assoc="hungarian",
                    use_kernels=True),
    max_pending=64, per_client_pending=16, rate=100.0, burst=20.0,
    ckpt_every=1)

SMOKE = SortServiceConfig(
    sort=SortConfig(max_trackers=8, max_detections=8, assoc="hungarian"),
    streams_per_chip=8, frames_per_segment=16)
