"""Mamba2-2.7B [arXiv:2405.21060]: 64L d=2560, SSD attention-free;
d_inner 5120 (expand 2), 80 heads of dim 64, state 128, conv 4, chunk 256;
vocab 50280 (GPT-NeoX), tied embeddings."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", num_layers=64, d_model=2560, block_type="ssm",
    d_ff=0, n_heads=0, n_kv_heads=0, vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256, ssm_conv=4,
    ssm_groups=1, max_seq_len=1048576)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", num_layers=3, d_model=64, block_type="ssm",
    d_ff=0, n_heads=0, n_kv_heads=0, vocab_size=512, tie_embeddings=True,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8, ssm_conv=4,
    ssm_groups=1, max_seq_len=256, dtype="float32")
