"""Qwen2.5-14B [hf]: 48L d=5120 40H (GQA kv 8) ff=13824, vocab 152064,
QKV bias."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b", num_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, head_dim=128, d_ff=13824, vocab_size=152064,
    # head_pad_factor=2: (40q, 8kv) -> (80q, 16kv) pads heads onto the
    # 16-way model axis (Perf iteration B1).  x2 padding preserves the GQA
    # grouping i//5 exactly and the padded block is zero -> identical math;
    # kills the partial-sharding all-reduce storm (2.2 TB/step -> see
    # EXPERIMENTS.md SPerf).
    head_pad_factor=2,
    qkv_bias=True, rope_theta=1e6, max_seq_len=32768)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", num_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, head_dim=8, d_ff=128, vocab_size=512, qkv_bias=True,
    rope_theta=1e6, max_seq_len=256, dtype="float32")
