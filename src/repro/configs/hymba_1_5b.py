"""Hymba-1.5B [arXiv:2411.13676]: 32L d=1600, parallel attn+mamba heads;
25 attn heads (GQA kv 5, head_dim 64) + Mamba2 path (d_inner 3200, 50 ssm
heads, state 16); sliding window 1024 with global attention at layers
{0, 15, 31}; ff=5504; vocab 32001.

Omitted vs. paper: the 128 learnable meta tokens (prompt-side detail,
noted in DESIGN.md §Arch-applicability)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hymba-1.5b", num_layers=32, d_model=1600, block_type="hybrid",
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab_size=32001,
    sliding_window=1024, global_attn_layers=(0, 15, 31),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256, ssm_conv=4,
    ssm_groups=1, rope_theta=1e4, max_seq_len=1048576)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", num_layers=3, d_model=64, block_type="hybrid",
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160, vocab_size=512,
    sliding_window=16, global_attn_layers=(0, 2), ssm_state=16,
    ssm_expand=2, ssm_head_dim=16, ssm_chunk=8, ssm_conv=4, ssm_groups=1,
    rope_theta=1e4, max_seq_len=256, dtype="float32")
