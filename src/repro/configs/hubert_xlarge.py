"""HuBERT-XLarge [arXiv:2106.07447]: 48L d=1280 16H ff=5120 encoder-only;
masked-prediction over 504 cluster codebook.  The conv waveform frontend is
a STUB — input_specs provides precomputed frame embeddings (paper-pool
rule)."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge", num_layers=48, d_model=1280, n_heads=16,
    n_kv_heads=16, d_ff=5120, vocab_size=504, causal=False,
    modality="audio", rope_theta=1e4, max_seq_len=32768)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", num_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=160, vocab_size=64, causal=False, modality="audio",
    rope_theta=1e4, max_seq_len=256, dtype="float32")
