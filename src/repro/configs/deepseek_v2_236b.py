"""DeepSeek-V2 236B [arXiv:2405.04434]: 60L d=5120 128H MLA
(q_lora 1536, kv_lora 512, nope 128 + rope 64, v 128); MoE: 160 routed
top-6 + 2 shared experts, per-expert ff 1536, first layer dense (ff 12288);
vocab 102400."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b", num_layers=60, d_model=5120, n_heads=128,
    n_kv_heads=128, d_ff=12288, vocab_size=102400, attn_type="mla",
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
    qk_rope_head_dim=64, v_head_dim=128,
    moe=True, n_routed_experts=160, n_shared_experts=2, moe_top_k=6,
    moe_d_ff=1536, first_k_dense=1, rope_theta=1e4, max_seq_len=32768)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke", num_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=192, vocab_size=512, attn_type="mla", q_lora_rank=48,
    kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    moe=True, n_routed_experts=8, n_shared_experts=2, moe_top_k=2,
    moe_d_ff=48, first_k_dense=1, rope_theta=1e4, max_seq_len=256,
    dtype="float32")
