"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: 62L d=2560 40H ff=6400,
vocab 73448, MLA (q_lora 768, kv_lora 256, nope 64 + rope 32, v 64).

Omitted vs. HF config: MiniCPM's mu-parametrization scaling constants
(scale_emb/scale_depth) — orthogonal to structure/layout; noted in
DESIGN.md."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b", num_layers=62, d_model=2560, n_heads=40,
    n_kv_heads=40, d_ff=6400, vocab_size=73448, attn_type="mla",
    q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
    qk_rope_head_dim=32, v_head_dim=64, rope_theta=1e4, max_seq_len=32768)

SMOKE = ModelConfig(
    name="minicpm3-4b-smoke", num_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=160, vocab_size=512, attn_type="mla", q_lora_rank=48,
    kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    rope_theta=1e4, max_seq_len=256, dtype="float32")
