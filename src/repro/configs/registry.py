"""Architecture registry: ``--arch <id>`` lookup, shape grid, input specs.

Each ``repro/configs/<id>.py`` exports ``FULL`` (the exact published config)
and ``SMOKE`` (a reduced same-family config for CPU tests).  This module
owns the (arch x shape) cell grid including the skip rules:

* encoder-only archs have no autoregressive step -> decode shapes skipped;
* ``long_500k`` requires sub-quadratic attention -> only SSM/hybrid run it.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

ARCH_IDS = [
    "qwen1_5_0_5b", "qwen2_7b", "minicpm3_4b", "qwen2_5_14b",
    "deepseek_v2_236b", "deepseek_v2_lite_16b", "hubert_xlarge",
    "mamba2_2_7b", "llava_next_mistral_7b", "hymba_1_5b",
]
# public names with dashes/dots accepted too
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({"qwen1.5-0.5b": "qwen1_5_0_5b", "qwen2-7b": "qwen2_7b",
                "minicpm3-4b": "minicpm3_4b", "qwen2.5-14b": "qwen2_5_14b",
                "deepseek-v2-236b": "deepseek_v2_236b",
                "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
                "hubert-xlarge": "hubert_xlarge", "mamba2-2.7b": "mamba2_2_7b",
                "llava-next-mistral-7b": "llava_next_mistral_7b",
                "hymba-1.5b": "hymba_1_5b"})


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

ARCHS: dict = {}


def _load(arch_id: str):
    if arch_id not in ARCHS:
        mod = importlib.import_module(f"repro.configs.{arch_id}")
        ARCHS[arch_id] = mod
    return ARCHS[arch_id]


def get_arch(name: str):
    arch_id = ALIASES.get(name, name)
    return _load(arch_id).FULL


def get_smoke(name: str):
    arch_id = ALIASES.get(name, name)
    return _load(arch_id).SMOKE


def skip_reason(arch_id: str, shape_name: str) -> Optional[str]:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if not cfg.causal and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape_name == "long_500k":
        sub_quadratic = cfg.block_type in ("ssm", "hybrid")
        if not sub_quadratic:
            return ("full quadratic attention: 500k decode skipped per spec "
                    "(see DESIGN.md §Arch-applicability)")
    return None


def cells(include_skipped: bool = False):
    """The 10 x 4 grid with skip annotations."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            r = skip_reason(a, s)
            if r is None or include_skipped:
                out.append((a, s, r))
    return out


# --------------------------------------------------------------- input specs
def input_specs(arch_name: str, shape_name: str, model=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``decode`` shapes need the cache pytree; pass a built ``model`` to avoid
    rebuilding (dry-run does), else it is derived via ``jax.eval_shape``.
    """
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    b, l = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    sd = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        if cfg.modality == "audio":
            batch = {"feats": sd((b, l, cfg.d_model), jnp.bfloat16),
                     "mask_spans": sd((b, l), jnp.bool_)}
            if shape.kind == "train":
                batch["labels"] = sd((b, l), i32)
                batch["loss_mask"] = sd((b, l), f32)
            return {"batch": batch}
        if cfg.modality == "vision":
            npatch = cfg.num_patches if shape.kind == "train" \
                else cfg.num_patches * 5  # anyres: base + 4 tiles
            text = l - npatch
            batch = {"tokens": sd((b, text), i32),
                     "patches": sd((b, npatch, cfg.frontend_dim),
                                   jnp.bfloat16)}
            if shape.kind == "train":
                batch["labels"] = sd((b, text), i32)
            return {"batch": batch}
        batch = {"tokens": sd((b, l), i32)}
        if shape.kind == "train":
            batch["labels"] = sd((b, l), i32)
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    from repro.models.model import build_model
    model = model or build_model(cfg)
    caches = jax.eval_shape(
        lambda: model.init_caches({"trunk": _trunk_like(cfg)}, b, l))
    return {"token": sd((b, 1), i32),
            "pos": sd((b,), i32),
            "caches": caches}


def _trunk_like(cfg):
    """Minimal trunk stand-in for cache shaping (init_caches only reads the
    segment plan, not the params)."""
    from repro.models.transformer import plan_segments
    return {"segments": [None] * len(plan_segments(cfg))}
