from .registry import ARCHS, SHAPES, get_arch, get_smoke, input_specs, cells  # noqa: F401
