"""Fused whole-frame SORT kernel (Pallas TPU) — one dispatch per frame.

The per-phase kernels in ``kalman_fused``/``iou_cost`` already collapse the
paper's ~15 tiny BLAS calls per tracker (Table IV) into three dispatches,
but the engine still pays launch + HBM round-trip overhead *between* them:
predicted state goes back to HBM, comes back in for the IoU kernel, the
cost matrix goes out, comes back for the update.  This kernel is the
paper's fusion argument taken to its limit: predict -> IoU cost -> greedy
association -> masked update execute in a **single** ``pallas_call`` with
the whole filter block resident in VMEM (DESIGN.md §2.3).

Layout: streams on lanes, tracker slots on sublane-tiled leading axes —
``x [7, T, S]``, ``p [49, T, S]``, ``det [D, 4, S]``, masks ``[*, S]``.
The grid is 1-D over stream blocks of ``block_s`` lanes; every phase is
trace-time-unrolled vector algebra over the block (the greedy rounds are
``min(D, T)`` masked argmaxes), so the MXU is never touched — contraction
dims are 4 and 7, the paper's "extremely small matrices".

VMEM per grid step at T=D=16, block_s=128:
(7+49)*16*128*4B (state in+out, x2) + 16*4*128*4B*2 (boxes) +
16*16*128*4B (IoU) ≈ 5.4 MiB — comfortably under the ~16 MiB budget.

Association (DESIGN.md §6): greedy (``core.greedy.greedy_assign_lane``)
runs *inside* the kernel — ``min(D, T)`` masked argmax rounds are plain
vector algebra.  The Hungarian solver's data-dependent augmenting paths do
not vectorize over lanes, so the paper-exact fused path
(``kernels/ops.py::frame_step(assoc="hungarian")``) instead solves the
lane-batched JV stage in jitted jnp *between* dispatch and kernel — the
precomputed ``trk_to_det`` enters this kernel as one extra ``[T, S]``
int32 operand and the predict/update phases stay resident: the ``[49, B]``
covariance still makes exactly one HBM round-trip per frame.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .kalman_fused import lane_spec

DEFAULT_BLOCK_S = 128


def _frame_kernel(x_ref, p_ref, det_ref, dm_ref, alive_ref, *refs,
                  iou_threshold: float, has_active: bool, has_assoc: bool,
                  has_class: bool, has_embed: bool, cost, num_classes: int):
    refs = list(refs)
    active = refs.pop(0)[...] if has_active else None
    t2d_in = refs.pop(0)[...] if has_assoc else None
    det_class = refs.pop(0)[...] if has_class else None
    trk_cls = refs.pop(0)[...] if has_class else None
    det_embed = refs.pop(0)[...] if has_embed else None
    trk_embed = refs.pop(0)[...] if has_embed else None
    xo_ref, po_ref, t2d_ref, md_ref = refs
    x, p, t2d, md = ref.frame_lane(
        x_ref[...], p_ref[...], det_ref[...], dm_ref[...], alive_ref[...],
        iou_threshold, active=active, trk_to_det=t2d_in,
        det_class=det_class, trk_cls=trk_cls,
        det_embed=det_embed, trk_embed=trk_embed,
        cost=cost, num_classes=num_classes)
    xo_ref[...] = x
    po_ref[...] = p
    t2d_ref[...] = t2d
    md_ref[...] = md.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("iou_threshold", "block_s", "interpret",
                                    "cost", "num_classes"))
def fused_frame(x, p, det, det_mask, alive, stream_active=None,
                trk_to_det=None, det_class=None, trk_cls=None,
                det_embed=None, trk_embed=None, *,
                iou_threshold: float = 0.3, cost=None, num_classes: int = 1,
                block_s: int = DEFAULT_BLOCK_S, interpret: bool = False):
    """One SORT frame for every stream in a single dispatch.

    ``x [7, T, S]``, ``p [49, T, S]``, ``det [D, 4, S]`` xyxy,
    ``det_mask [D, S]`` 0/1 float, ``alive [T, S]`` 0/1 float;
    ``S % block_s == 0``.  ``stream_active [1, S]`` 0/1 float (optional)
    is the ragged-stream lane mask (DESIGN.md §3): inactive lanes pass
    through the kernel as exact no-ops, so finished sequences cost no
    extra dispatch while they wait for a recycled admission.

    ``trk_to_det [T, S] int32`` (optional) is a precomputed, already-gated
    assignment (DESIGN.md §6): the kernel then skips its in-VMEM IoU +
    greedy phases and runs predict -> gather-by-assignment -> masked
    update — the fused-Hungarian path, whose JV solve stage ran outside.

    ``cost`` (``core.cost.CostSpec``, static) + ``num_classes`` activate
    the pluggable association score/gate (DESIGN.md §10) with its
    conditional lane operands — ``det_class [D, S]`` / ``trk_cls [T, S]``
    int32 and ``det_embed [D, E, S]`` / ``trk_embed [E, T, S]`` — each a
    block-sliced VMEM input only when present, exactly like
    ``stream_active``/``trk_to_det``.
    Returns ``(x, p, trk_to_det [T, S] int32, matched_det [D, S] int32)``.
    """
    t, s = x.shape[1], x.shape[2]
    d = det.shape[0]
    assert s % block_s == 0, (s, block_s)
    has_class = det_class is not None
    has_embed = det_embed is not None
    assert has_class == (trk_cls is not None)
    assert has_embed == (trk_embed is not None)

    def spec3(a, b):
        return pl.BlockSpec((a, b, block_s), lambda i: (0, 0, i))

    operands = [x, p, det, det_mask, alive]
    in_specs = [spec3(7, t), spec3(49, t), spec3(d, 4),
                lane_spec(d, block_s), lane_spec(t, block_s)]
    if stream_active is not None:
        operands.append(stream_active)
        in_specs.append(lane_spec(1, block_s))
    if trk_to_det is not None:
        operands.append(trk_to_det)
        in_specs.append(lane_spec(t, block_s))
    if has_class:
        operands += [det_class, trk_cls]
        in_specs += [lane_spec(d, block_s), lane_spec(t, block_s)]
    if has_embed:
        e = det_embed.shape[1]
        operands += [det_embed, trk_embed]
        in_specs += [spec3(d, e), spec3(e, t)]

    return pl.pallas_call(
        functools.partial(_frame_kernel, iou_threshold=iou_threshold,
                          has_active=stream_active is not None,
                          has_assoc=trk_to_det is not None,
                          has_class=has_class, has_embed=has_embed,
                          cost=cost, num_classes=num_classes),
        grid=(s // block_s,),
        in_specs=in_specs,
        out_specs=[spec3(7, t), spec3(49, t),
                   lane_spec(t, block_s), lane_spec(d, block_s)],
        out_shape=[jax.ShapeDtypeStruct((7, t, s), x.dtype),
                   jax.ShapeDtypeStruct((49, t, s), p.dtype),
                   jax.ShapeDtypeStruct((t, s), jnp.int32),
                   jax.ShapeDtypeStruct((d, s), jnp.int32)],
        interpret=interpret,
    )(*operands)
