"""Pure-jnp oracles for the Pallas kernels, in the kernels' *lane layout*.

Layout convention (DESIGN.md §2): the tracker batch axis ``B`` lives on the
TPU lane dimension.  State is ``x [7, B]``, covariance ``p [49, B]`` (row-
major flattened 7x7), observation ``z [4, B]``, mask ``m [1, B]`` (f32 0/1).

These oracles are the ground truth for ``tests/test_kernels.py`` and the
CPU fallback for ``ops.py``.  They are algebraically identical to
``repro.core.kalman`` (which is itself validated against the numpy
reference), just transposed.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# SORT filter constants in lane form -------------------------------------
Q_DIAG = (1.0, 1.0, 1.0, 1.0, 0.01, 0.01, 1e-4)
R_DIAG = (1.0, 1.0, 10.0, 10.0)


def _idx(i: int, j: int) -> int:
    return i * 7 + j


def predict_mean_lane(x: jnp.ndarray) -> jnp.ndarray:
    """The mean half of :func:`predict_lane` — ``x [7, ...]`` only.

    Used standalone by the fused-Hungarian association stage
    (``kernels/ops.py``), which needs the predicted boxes but not the
    covariance: recomputing these 7 rows in plain jnp is free next to
    keeping the 49-row covariance resident in the kernel.
    """
    ds = jnp.where(x[2] + x[6] <= 0.0, 0.0, x[6])
    return jnp.stack([x[0] + x[4], x[1] + x[5], x[2] + ds, x[3],
                      x[4], x[5], ds], axis=0)


def predict_lane(x: jnp.ndarray, p: jnp.ndarray):
    """Constant-velocity predict on lane layout. ``x [7,B]``, ``p [49,B]``."""
    x_new = predict_mean_lane(x)

    def fp(i, j):  # (F P F^T)[i, j] exploiting F = I + shift(0..2 -> 4..6)
        v = p[_idx(i, j)]
        if i < 3:
            v = v + p[_idx(i + 4, j)]
        if j < 3:
            v = v + p[_idx(i, j + 4)]
        if i < 3 and j < 3:
            v = v + p[_idx(i + 4, j + 4)]
        return v

    rows = [fp(i, j) + (Q_DIAG[i] if i == j else 0.0)
            for i in range(7) for j in range(7)]
    return x_new, jnp.stack(rows, axis=0)


def predict_cov4_lane(p: jnp.ndarray):
    """Top-left 4x4 block of the *predicted* covariance, from the
    pre-predict ``p [49, ...]`` — as nested ``[[...]]`` lists of lane
    arrays (the form ``core.cost`` consumes for the Mahalanobis gate).

    This is :func:`predict_lane`'s ``fp`` recurrence restricted to
    ``i, j < 4``, with the identical accumulation order, so each entry is
    bit-identical to row ``_idx(i, j)`` of the predicted covariance.  The
    fused-Hungarian pre-pass (``kernels/ops.py``) uses it to evaluate the
    gate *outside* the kernel on exactly the floats the in-kernel
    ``frame_lane`` path sees post-predict — the dispatch-mode bit-parity
    contract of ``tests/test_oracle_parity.py``.
    """
    def fp(i, j):
        v = p[_idx(i, j)]
        if i < 3:
            v = v + p[_idx(i + 4, j)]
        if j < 3:
            v = v + p[_idx(i, j + 4)]
        if i < 3 and j < 3:
            v = v + p[_idx(i + 4, j + 4)]
        return v

    return [[fp(i, j) + (Q_DIAG[i] if i == j else 0.0) for j in range(4)]
            for i in range(4)]


def _inv2(m00, m01, m10, m11):
    det = m00 * m11 - m01 * m10
    inv = 1.0 / det
    return m11 * inv, -m01 * inv, -m10 * inv, m00 * inv


def update_lane(x: jnp.ndarray, p: jnp.ndarray, z: jnp.ndarray,
                mask: jnp.ndarray):
    """Masked measurement update on lane layout.

    ``x [7,B]``, ``p [49,B]``, ``z [4,B]``, ``mask [1,B]`` (0/1 f32).
    """
    y = [z[i] - x[i] for i in range(4)]
    # S = P[0:4, 0:4] + diag(R)
    s = [[p[_idx(i, j)] + (R_DIAG[i] if i == j else 0.0)
          for j in range(4)] for i in range(4)]
    sinv = _inv4(s)
    # K = P[:, 0:4] @ Sinv  -> [7][4] of (B,) vectors
    k = [[sum(p[_idx(i, kk)] * sinv[kk][j] for kk in range(4))
          for j in range(4)] for i in range(7)]
    x_new = jnp.stack(
        [x[i] + sum(k[i][j] * y[j] for j in range(4)) for i in range(7)], 0)
    # P_new = (I - K H) P ;  (K H)[i, j] = K[i, j] for j < 4 else 0
    p_new = jnp.stack(
        [p[_idx(i, j)] - sum(k[i][kk] * p[_idx(kk, j)] for kk in range(4))
         for i in range(7) for j in range(7)], 0)
    m = mask[0]
    return (m * x_new + (1.0 - m) * x), (m * p_new + (1.0 - m) * p)


def _inv4(s):
    """Blockwise inverse of SPD 4x4 given as [[ (B,) x4 ] x4]."""
    a00, a01, a10, a11 = s[0][0], s[0][1], s[1][0], s[1][1]
    b00, b01, b10, b11 = s[0][2], s[0][3], s[1][2], s[1][3]
    c00, c01, c10, c11 = s[2][0], s[2][1], s[3][0], s[3][1]
    d00, d01, d10, d11 = s[2][2], s[2][3], s[3][2], s[3][3]
    ai00, ai01, ai10, ai11 = _inv2(a00, a01, a10, a11)
    # C A^-1 (2x2)
    ca00 = c00 * ai00 + c01 * ai10
    ca01 = c00 * ai01 + c01 * ai11
    ca10 = c10 * ai00 + c11 * ai10
    ca11 = c10 * ai01 + c11 * ai11
    # A^-1 B (2x2)
    ab00 = ai00 * b00 + ai01 * b10
    ab01 = ai00 * b01 + ai01 * b11
    ab10 = ai10 * b00 + ai11 * b10
    ab11 = ai10 * b01 + ai11 * b11
    # Schur = D - C A^-1 B
    s00 = d00 - (ca00 * b00 + ca01 * b10)
    s01 = d01 - (ca00 * b01 + ca01 * b11)
    s10 = d10 - (ca10 * b00 + ca11 * b10)
    s11 = d11 - (ca10 * b01 + ca11 * b11)
    si00, si01, si10, si11 = _inv2(s00, s01, s10, s11)
    # TL = Ai + AB @ Si @ CA ; TR = -AB @ Si ; BL = -Si @ CA ; BR = Si
    absi00 = ab00 * si00 + ab01 * si10
    absi01 = ab00 * si01 + ab01 * si11
    absi10 = ab10 * si00 + ab11 * si10
    absi11 = ab10 * si01 + ab11 * si11
    tl00 = ai00 + absi00 * ca00 + absi01 * ca10
    tl01 = ai01 + absi00 * ca01 + absi01 * ca11
    tl10 = ai10 + absi10 * ca00 + absi11 * ca10
    tl11 = ai11 + absi10 * ca01 + absi11 * ca11
    tr00, tr01 = -absi00, -absi01
    tr10, tr11 = -absi10, -absi11
    bl00 = -(si00 * ca00 + si01 * ca10)
    bl01 = -(si00 * ca01 + si01 * ca11)
    bl10 = -(si10 * ca00 + si11 * ca10)
    bl11 = -(si10 * ca01 + si11 * ca11)
    return [[tl00, tl01, tr00, tr01],
            [tl10, tl11, tr10, tr11],
            [bl00, bl01, si00, si01],
            [bl10, bl11, si10, si11]]


_EPS = 1e-9


def z_to_xyxy_lane(x: jnp.ndarray) -> jnp.ndarray:
    """Lane-layout ``bbox.z_to_xyxy``: ``x [>=4, ...]`` -> boxes ``[..., 4]``
    stacked on a *new* axis 1 when input is ``[7, T, B]`` -> ``[T, 4, B]``."""
    u, v = x[0], x[1]
    s = jnp.maximum(x[2], 0.0)
    r = jnp.maximum(x[3], _EPS)
    w = jnp.sqrt(s * r)
    h = s / jnp.maximum(w, _EPS)
    half_w, half_h = w / 2.0, h / 2.0
    return jnp.stack([u - half_w, v - half_h, u + half_w, v + half_h],
                     axis=1 if x.ndim == 3 else 0)


def xyxy_to_z_lane(box: jnp.ndarray) -> jnp.ndarray:
    """Lane-layout ``bbox.xyxy_to_z``: ``box [D, 4, B]`` -> ``z [4, D, B]``."""
    x1, y1, x2, y2 = box[:, 0], box[:, 1], box[:, 2], box[:, 3]
    w = x2 - x1
    h = y2 - y1
    u = x1 + w / 2.0
    v = y1 + h / 2.0
    s = w * h
    r = w / jnp.maximum(h, _EPS)
    return jnp.stack([u, v, s, r], axis=0)


def frame_lane(x: jnp.ndarray, p: jnp.ndarray, det: jnp.ndarray,
               det_mask: jnp.ndarray, alive: jnp.ndarray,
               iou_threshold: float = 0.3,
               active: jnp.ndarray | None = None,
               assoc: str = "greedy",
               trk_to_det: jnp.ndarray | None = None,
               det_class: jnp.ndarray | None = None,
               trk_cls: jnp.ndarray | None = None,
               det_embed: jnp.ndarray | None = None,
               trk_embed: jnp.ndarray | None = None,
               cost=None, num_classes: int = 1):
    """One whole SORT frame (predict -> IoU -> assign -> masked update) as
    pure lane-layout vector algebra — the oracle for the single-dispatch
    ``kernels.frame.fused_frame`` Pallas kernel.

    Shapes (DESIGN.md §2; streams on lanes, tracker slots on sublanes):
    ``x [7, T, S]``, ``p [49, T, S]``, ``det [D, 4, S]`` xyxy,
    ``det_mask [D, S]`` (bool or 0/1 float), ``alive [T, S]``.

    ``active [1, S]`` (bool or 0/1 float, optional) is the ragged-stream
    lane mask (DESIGN.md §3): lanes with ``active == 0`` are exact no-ops —
    their detections are masked out (no matches, so ``trk_to_det == -1``
    and ``matched_det == False`` fall out of the association gate) and
    their state is restored after predict/update, bit-identical to never
    having run the frame.

    ``assoc`` selects the association algorithm (DESIGN.md §6):
    ``"greedy"`` (best-first masked argmax rounds) or ``"hungarian"``
    (lane-batched JV solve, ``core.association.associate_lane`` — the
    paper's algorithm).  Alternatively ``trk_to_det [T, S] int32`` supplies
    a *precomputed* assignment and skips the IoU/association phases
    entirely: this is how the Pallas kernel body consumes the fused-
    Hungarian path, whose JV solve runs as a jitted stage **outside** the
    kernel (data-dependent augmenting paths don't vectorize over lanes)
    while predict and update stay resident.

    ``cost`` (a ``core.cost.CostSpec``) + ``num_classes`` activate the
    pluggable association cost (DESIGN.md §10) with its lane-major
    operands: ``det_class [D, S]`` / ``trk_cls [T, S]`` int32 for the
    class partition, ``det_embed [D, E, S]`` / ``trk_embed [E, T, S]``
    for the appearance term.  Score/feasibility are evaluated on the
    *post-predict* state, then feed the same association entry points —
    ``cost=None`` (or the pure-IoU single-class spec) leaves every solver
    argument byte-identical to the pre-cost path.

    Returns ``(x, p, trk_to_det [T, S] int32, matched_det [D, S] bool)``.
    Tracker lifecycle (tick/birth) stays outside: it is integer bookkeeping
    off the covariance hot path.
    """
    from repro.core.greedy import greedy_assign_lane

    x_in, p_in = x, p
    if active is not None:
        det_mask = det_mask * (active > 0)                  # [D,S] & [1,S]
    x, p = predict_lane(x, p)                               # [7,T,S], [49,T,S]
    if trk_to_det is not None:
        # precomputed assignment (already gated): a matching, so matched
        # detections are exactly the assigned values >= 0
        d = det.shape[0]
        di_iota = jnp.arange(d, dtype=jnp.int32).reshape(
            (d, 1) + (1,) * (trk_to_det.ndim - 1))
        matched_det = (trk_to_det[None] == di_iota).any(axis=1)
    else:
        trk_boxes = z_to_xyxy_lane(x[:4])                   # [T, 4, S]
        iou = iou_lane(det, trk_boxes)                      # [D, T, S]
        score = feasible = None
        if cost is not None:
            from repro.core import cost as cost_mod
            if (cost_mod.needs_score(cost)
                    or cost_mod.needs_feasible(cost, num_classes)):
                p4 = ([[p[_idx(i, j)] for j in range(4)] for i in range(4)]
                      if cost.uses_maha else None)
                score, feasible = cost_mod.score_and_feasible_lane(
                    iou, cost, num_classes=num_classes,
                    det_class=det_class, trk_cls=trk_cls,
                    det_embed=det_embed, trk_embed=trk_embed,
                    z_det=xyxy_to_z_lane(det) if cost.uses_maha else None,
                    x_pred=x, p4_pred=p4)
        if assoc == "hungarian":
            from repro.core.association import associate_lane
            trk_to_det, matched_det = associate_lane(
                iou, det_mask, alive, iou_threshold,
                score=score, feasible=feasible)
        elif assoc == "greedy":
            trk_to_det, matched_det = greedy_assign_lane(
                iou, det_mask, alive, iou_threshold,
                score=score, feasible=feasible)
        else:
            raise ValueError(f"unknown assoc {assoc!r}")
    # gather each matched tracker's observation via one-hot contraction
    # over D (D <= ~16, trace-time unrolled; no per-lane dynamic gather)
    z_all = xyxy_to_z_lane(det)                             # [4, D, S]
    d = det.shape[0]
    z_trk = jnp.zeros_like(x[:4])                           # [4, T, S]
    for di in range(d):
        sel = (trk_to_det == di)[None]                      # [1, T, S]
        z_trk = jnp.where(sel, z_all[:, di][:, None], z_trk)
    mask = (trk_to_det >= 0).astype(x.dtype)[None]          # [1, T, S]
    x, p = update_lane(x, p, z_trk, mask)
    if active is not None:
        keep = (active > 0)[:, None]                        # [1, 1, S]
        x = jnp.where(keep, x, x_in)
        p = jnp.where(keep, p, p_in)
    return x, p, trk_to_det, matched_det


# ------------------------------------------------------------------------
# Chunk-resident execution (DESIGN.md §9): the whole serving step — masked
# lane re-init, fused frame, tracker lifecycle, emit — as kernel-safe
# lane-layout vector algebra, so the megakernel (`kernels.chunk.fused_chunk`)
# can unroll it once per frame of its in-kernel frame loop and stay
# bit-identical to F per-frame dispatches of `core.sort`'s scan.
# ------------------------------------------------------------------------
class ChunkState(NamedTuple):
    """Per-lane SORT state as a flat bundle of numeric arrays — the carried
    state of the chunk-resident megakernel (DESIGN.md §9).

    ``core.sort.LaneSortState`` nests a bool-typed ``SlotPool`` and mixes
    per-stream scalars; a Pallas kernel wants one flat tuple of >=2-D
    numeric operands with a uniform lane axis.  Every lifecycle field is
    int32 (``alive`` included: 0/1), per-stream counters carry a leading
    unit sublane axis: ``x [7, T, S]``, ``p [49, T, S]``, slot fields
    ``[T, S]``, ``next_uid``/``frame_count`` ``[1, S]``.
    ``core.sort.chunk_state_of`` / ``lane_state_of_chunk`` convert exactly.

    ``embed`` is the per-track appearance embedding (DESIGN.md §10),
    ``[E, T, S]`` with ``E = cost.embed_dim`` — a zero-size ``[0, T, S]``
    array when the cost has no appearance term.  It sits *last* so the
    megakernel can drop it from the Pallas operand list when unused
    (``kernels/chunk.py``) without renumbering the other state blocks.
    """

    x: jnp.ndarray                  # [7, T, S]  Kalman means
    p: jnp.ndarray                  # [49, T, S] covariances
    alive: jnp.ndarray              # [T, S] int32 0/1
    age: jnp.ndarray                # [T, S] int32
    hits: jnp.ndarray               # [T, S] int32
    hit_streak: jnp.ndarray         # [T, S] int32
    time_since_update: jnp.ndarray  # [T, S] int32
    uid: jnp.ndarray                # [T, S] int32, -1 when dead
    cls: jnp.ndarray                # [T, S] int32 class, -1 when dead
    next_uid: jnp.ndarray           # [1, S] int32
    frame_count: jnp.ndarray        # [1, S] int32
    embed: jnp.ndarray              # [E, T, S] appearance embeddings


class ChunkOuts(NamedTuple):
    """Per-frame outputs of the chunk body; stacked ``[F, ...]`` by
    :func:`chunk_lane` / the megakernel's frame-indexed output blocks."""

    boxes: jnp.ndarray        # [T, 4, S]
    uid: jnp.ndarray          # [T, S] int32
    emit: jnp.ndarray         # [T, S] bool (int32 across the kernel ABI)
    trk_to_det: jnp.ndarray   # [T, S] int32
    matched_det: jnp.ndarray  # [D, S] bool (int32 across the kernel ABI)
    cls: jnp.ndarray          # [T, S] int32 track class, -1 when dead


def assign_slots_lane_unrolled(free_mask: jnp.ndarray,
                               want_mask: jnp.ndarray) -> jnp.ndarray:
    """Kernel-safe ``slots.assign_slots_lane``: the same rank matching
    (the k-th claimant takes the k-th free slot, -1 when the pool is
    exhausted) computed with trace-time-unrolled compare/accumulate
    instead of cumsum + scatter + ``take_along_axis``, which don't lower
    inside a Pallas TPU kernel body.  ``free [T, ...]`` bool,
    ``want [D, ...]`` bool -> ``slot_for [D, ...] int32``; integer-exact
    vs the scatter version (``tests/test_lane.py`` locks the equivalence).
    """
    t, d = free_mask.shape[0], want_mask.shape[0]
    zero = jnp.zeros(free_mask.shape[1:], jnp.int32)
    free_rank = []                    # free slots with index < ti
    num_free = zero
    for ti in range(t):
        free_rank.append(num_free)
        num_free = num_free + free_mask[ti].astype(jnp.int32)
    want_rank = []                    # claimants with index < di
    acc = zero
    for di in range(d):
        want_rank.append(acc)
        acc = acc + want_mask[di].astype(jnp.int32)
    rows = []
    for di in range(d):
        ok = want_mask[di] & (want_rank[di] < num_free)
        slot = jnp.full(free_mask.shape[1:], -1, jnp.int32)
        for ti in range(t):
            hit = free_mask[ti] & (free_rank[ti] == want_rank[di])
            slot = jnp.where(ok & hit, ti, slot)
        rows.append(slot)
    return jnp.stack(rows, axis=0)


def step_chunk_lane(state: ChunkState, det: jnp.ndarray,
                    det_mask: jnp.ndarray, active: jnp.ndarray,
                    reset: jnp.ndarray,
                    trk_to_det: Optional[jnp.ndarray] = None,
                    det_class: Optional[jnp.ndarray] = None,
                    det_embed: Optional[jnp.ndarray] = None, *,
                    iou_threshold: float = 0.3, max_age: int = 1,
                    min_hits: int = 3, assoc: str = "greedy",
                    cost=None, num_classes: int = 1):
    """One serving step of the chunk-resident body (DESIGN.md §9).

    Replicates, op for op, what the serving scan runs per frame —
    ``core.sort.reset_ragged`` followed by ``SortEngine.lane_step``
    (masked lane re-init, fused predict/IoU/assign/update, tick, births,
    inactive-lane freeze, emit) — restricted to operations that lower
    inside a Pallas TPU kernel body, so the megakernel that runs this
    once per frame of its in-kernel loop is bit-identical to F per-frame
    dispatches.

    ``det [D, 4, S]`` xyxy, ``det_mask [D, S]`` 0/1 in state dtype,
    ``active [1, S]`` 0/1 in state dtype, ``reset [1, S]`` 0/1 numeric;
    ``trk_to_det [T, S] int32`` (optional) is the precomputed association
    for the fused-Hungarian path (see :func:`frame_lane`).
    ``det_class [D, S] int32`` / ``det_embed [D, E, S]`` (optional) are
    the pluggable-cost operands (DESIGN.md §10); with a multi-term
    ``cost`` / ``num_classes`` they feed the in-step score/gate, stamp
    births (class, embedding) and refresh matched tracks' embeddings —
    in the *same unrolled per-detection order* as the per-frame engine
    path (``core.sort.SortEngine.lane_step``), keeping chunk vs frame
    dispatch bit-identical.
    Returns ``(ChunkState, ChunkOuts)``.
    """
    from repro.core import kalman, slots

    dt = state.x.dtype
    t = state.alive.shape[0]
    d = det.shape[0]
    act = active[0] > 0                                      # [S]
    rst = reset[0] > 0                                       # [S]

    # masked lane re-init (reset_lanes semantics, uid_start=1): a recycled
    # lane and its admitted sequence's first frame share the step.  The
    # initial covariance enters as 49 scalar selects, not a [49] array —
    # Pallas kernel bodies may not capture non-scalar constants, and the
    # scalar path is bit-identical (every entry is exactly representable).
    p0 = tuple(float(v) for v in
               kalman.initial_covariance_np().astype(dt).reshape(49))
    x = jnp.where(rst[None, None], jnp.zeros((), dt), state.x)
    p = jnp.stack([jnp.where(rst[None], v, state.p[i])
                   for i, v in enumerate(p0)], axis=0)
    e = state.embed.shape[0]
    emb = state.embed
    if e > 0:
        emb = jnp.where(rst[None, None], jnp.zeros((), dt), emb)
    zero = jnp.zeros((), jnp.int32)
    alive0 = (state.alive > 0) & ~rst[None]
    pool0 = slots.SlotPool(
        alive=alive0,
        age=jnp.where(rst[None], zero, state.age),
        hits=jnp.where(rst[None], zero, state.hits),
        hit_streak=jnp.where(rst[None], zero, state.hit_streak),
        time_since_update=jnp.where(rst[None], zero,
                                    state.time_since_update),
        uid=jnp.where(rst[None], -1, state.uid),
        cls=jnp.where(rst[None], -1, state.cls),
        next_uid=jnp.where(rst, 1, state.next_uid[0]),       # [S]
    )
    fc0 = jnp.where(rst, zero, state.frame_count[0])         # [S]

    # 1-3. fused predict + IoU + assign + masked update — the same body
    # the per-frame kernel runs (inactive lanes restored inside)
    x, p, t2d, matched = frame_lane(
        x, p, det, det_mask, alive0.astype(dt), iou_threshold,
        active=active, assoc=assoc, trk_to_det=trk_to_det,
        det_class=det_class, trk_cls=pool0.cls,
        det_embed=det_embed, trk_embed=emb,
        cost=cost, num_classes=num_classes)

    # 4a. age & kill (elementwise)
    pool = slots.tick(pool0, t2d >= 0, max_age)

    # 4b. births from unmatched detections into free slots (kernel-safe
    # rank matching + unrolled one-hot scatter over the T x D grid)
    unmatched = (det_mask > 0) & ~matched & act[None]
    slot_for = assign_slots_lane_unrolled(~pool.alive, unmatched)
    z_det = xyxy_to_z_lane(det)                              # [4, D, S]
    claimed = slot_for >= 0
    born_order = []                                          # claimants < di
    n_born = jnp.zeros(slot_for.shape[1:], jnp.int32)
    for di in range(d):
        born_order.append(n_born)
        n_born = n_born + claimed[di].astype(jnp.int32)
    born_rows, uid_rows, cls_rows, zb_rows = [], [], [], []
    for ti in range(t):
        sel_any = jnp.zeros(slot_for.shape[1:], bool)
        uid_t = pool.uid[ti]
        cls_t = pool.cls[ti]
        zb_t = jnp.zeros((4,) + slot_for.shape[1:], dt)
        for di in range(d):
            sel = slot_for[di] == ti      # claimed slots are distinct
            sel_any = sel_any | sel
            uid_t = jnp.where(sel, pool.next_uid + born_order[di], uid_t)
            cls_t = jnp.where(
                sel, zero if det_class is None else det_class[di], cls_t)
            zb_t = jnp.where(sel[None], z_det[:, di], zb_t)
        born_rows.append(sel_any)
        uid_rows.append(uid_t)
        cls_rows.append(cls_t)
        zb_rows.append(zb_t)
    born = jnp.stack(born_rows, axis=0)                      # [T, S]
    zb = jnp.stack(zb_rows, axis=1)                          # [4, T, S]
    pool = slots.SlotPool(
        alive=pool.alive | born,
        age=jnp.where(born, zero, pool.age),
        hits=jnp.where(born, zero, pool.hits),
        hit_streak=jnp.where(born, zero, pool.hit_streak),
        time_since_update=jnp.where(born, zero, pool.time_since_update),
        uid=jnp.stack(uid_rows, axis=0),
        cls=jnp.stack(cls_rows, axis=0),
        next_uid=pool.next_uid + n_born,
    )
    x_init = jnp.concatenate([zb, jnp.zeros((3,) + zb.shape[1:], dt)], 0)
    x = jnp.where(born[None], x_init, x)
    p = jnp.stack([jnp.where(born, v, p[i]) for i, v in enumerate(p0)],
                  axis=0)

    # embedding refresh: matched tracks take their matched detection's
    # embedding (replace), born tracks their claiming detection's — the
    # same unrolled per-detection loop order as the per-frame engine path
    # (`SortEngine.lane_step`), for chunk-vs-frame bit parity.
    if e > 0 and det_embed is not None:
        ti_iota = jnp.arange(t, dtype=jnp.int32)[:, None]    # [T, 1]
        for di in range(d):
            m_sel = (t2d == di)[None]                        # [1, T, S]
            emb = jnp.where(m_sel, det_embed[di][:, None], emb)
        for di in range(d):
            b_sel = (slot_for[di][None, :] == ti_iota)[None]  # [1, T, S]
            emb = jnp.where(b_sel, det_embed[di][:, None], emb)

    # inactive lanes: lifecycle freezes (x/p were restored inside
    # frame_lane; births can't fire — `unmatched` was gated by act)
    def sel(new, old):
        return jnp.where(act[None], new, old)

    pool = slots.SlotPool(
        alive=sel(pool.alive, pool0.alive),
        age=sel(pool.age, pool0.age),
        hits=sel(pool.hits, pool0.hits),
        hit_streak=sel(pool.hit_streak, pool0.hit_streak),
        time_since_update=sel(pool.time_since_update,
                              pool0.time_since_update),
        uid=sel(pool.uid, pool0.uid),
        cls=sel(pool.cls, pool0.cls),
        next_uid=jnp.where(act, pool.next_uid, pool0.next_uid),
    )
    fc = fc0 + act.astype(jnp.int32)                         # [S]

    # 5. emit: updated this frame AND (probation passed OR warmup)
    warmup = (fc <= min_hits)[None]                          # [1, S]
    emit = (pool.alive & (pool.time_since_update < 1)
            & ((pool.hit_streak >= min_hits) | warmup) & act[None])
    new_state = ChunkState(
        x=x, p=p, alive=pool.alive.astype(jnp.int32), age=pool.age,
        hits=pool.hits, hit_streak=pool.hit_streak,
        time_since_update=pool.time_since_update, uid=pool.uid,
        cls=pool.cls,
        next_uid=pool.next_uid[None, :], frame_count=fc[None, :],
        embed=emb)
    outs = ChunkOuts(boxes=z_to_xyxy_lane(x[:4]), uid=pool.uid, emit=emit,
                     trk_to_det=t2d, matched_det=matched, cls=pool.cls)
    return new_state, outs


def chunk_lane(state: ChunkState, det: jnp.ndarray, det_mask: jnp.ndarray,
               active: jnp.ndarray, reset: jnp.ndarray,
               trk_to_det: Optional[jnp.ndarray] = None,
               det_class: Optional[jnp.ndarray] = None,
               det_embed: Optional[jnp.ndarray] = None, *,
               iou_threshold: float = 0.3, max_age: int = 1,
               min_hits: int = 3, assoc: str = "greedy",
               cost=None, num_classes: int = 1):
    """Chunk-level oracle: scan :func:`step_chunk_lane` over the frame
    axis — the ground truth for ``kernels.chunk.fused_chunk`` and the
    non-TPU execution path of ``kernels.ops.chunk_step``.

    ``det [F, D, 4, S]``, ``det_mask [F, D, S]``, ``active``/``reset``
    ``[F, 1, S]``, optional ``trk_to_det [F, T, S] int32``,
    ``det_class [F, D, S] int32``, ``det_embed [F, D, E, S]``.  Returns
    ``(ChunkState, ChunkOuts stacked over F)``.
    """
    present = [a is not None for a in (trk_to_det, det_class, det_embed)]

    def body(st, inp):
        d_, m_, a_, r_ = inp[:4]
        it = iter(inp[4:])
        t2, dc, de = (next(it) if has else None for has in present)
        return step_chunk_lane(st, d_, m_, a_, r_, t2, dc, de,
                               iou_threshold=iou_threshold, max_age=max_age,
                               min_hits=min_hits, assoc=assoc,
                               cost=cost, num_classes=num_classes)

    xs = (det, det_mask, active, reset) + tuple(
        a for a in (trk_to_det, det_class, det_embed) if a is not None)
    return jax.lax.scan(body, state, xs)


def iou_lane(det: jnp.ndarray, trk: jnp.ndarray) -> jnp.ndarray:
    """IoU on lane layout: ``det [D, 4, B]``, ``trk [T, 4, B]`` -> ``[D, T, B]``."""
    d, t = det.shape[0], trk.shape[0]
    rows = []
    for i in range(d):
        for j in range(t):
            ax1, ay1, ax2, ay2 = det[i, 0], det[i, 1], det[i, 2], det[i, 3]
            bx1, by1, bx2, by2 = trk[j, 0], trk[j, 1], trk[j, 2], trk[j, 3]
            iw = jnp.maximum(jnp.minimum(ax2, bx2) - jnp.maximum(ax1, bx1), 0.0)
            ih = jnp.maximum(jnp.minimum(ay2, by2) - jnp.maximum(ay1, by1), 0.0)
            inter = iw * ih
            ua = jnp.maximum(ax2 - ax1, 0.0) * jnp.maximum(ay2 - ay1, 0.0)
            ub = jnp.maximum(bx2 - bx1, 0.0) * jnp.maximum(by2 - by1, 0.0)
            rows.append(inter / jnp.maximum(ua + ub - inter, 1e-9))
    return jnp.stack(rows, 0).reshape(d, t, -1)
