"""Fused batched Kalman kernels (Pallas TPU).

The paper's Table IV decomposes each SORT step into ~15 tiny BLAS calls
(DGEMM/DGEMV/transpose/inverse on 7x7 / 4x7 / 4x4 matrices); its C rewrite
wins mainly by collapsing dispatch overhead.  The TPU analogue: one Pallas
kernel per phase that keeps the *entire* filter block resident in VMEM and
executes the whole tiny-matrix chain as unrolled vector ops, with the
tracker batch ``B`` on the lane dimension — each scalar MAC of the 7x7
algebra becomes one VPU op over ``block_b`` trackers.

Layouts (see ``kernels/ref.py``): ``x [7, B]``, ``p [49, B]`` (row-major
7x7), ``z [4, B]``, ``mask [1, B]``.  The MXU is deliberately *not* used:
contraction dims are 4 and 7, two orders of magnitude below the 128x128
systolic array — the paper's "strong scaling loses" result, transposed to
hardware units.

Grid: 1-D over lane blocks; BlockSpec pins every operand's sublane extent
(7 / 49 / 4 / 1, padded to 8-sublane tiles by Mosaic) and tiles only lanes.
VMEM per grid step at block_b=512: (7+49+4+1+7+49) * 512 * 4B ≈ 234 KiB.

These per-phase kernels still dispatch (and round-trip HBM) three times
per frame; ``kernels/frame.py`` fuses the whole frame — including the IoU
cost and greedy association between predict and update — into a single
dispatch over the persistent lane state (DESIGN.md §2.3).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_B = 512


def _predict_kernel(x_ref, p_ref, xo_ref, po_ref):
    x = x_ref[...]
    p = p_ref[...]
    x_new, p_new = ref.predict_lane(x, p)  # trace-time unrolled vector algebra
    xo_ref[...] = x_new
    po_ref[...] = p_new


def _update_kernel(x_ref, p_ref, z_ref, m_ref, xo_ref, po_ref):
    x = x_ref[...]
    p = p_ref[...]
    z = z_ref[...]
    m = m_ref[...]
    x_new, p_new = ref.update_lane(x, p, z, m)
    xo_ref[...] = x_new
    po_ref[...] = p_new


def _step_kernel(x_ref, p_ref, z_ref, m_ref, xo_ref, po_ref):
    """Fully fused predict+update (used by the lane-layout fast path when the
    association for this frame is already known, e.g. re-simulation replay)."""
    x, p = ref.predict_lane(x_ref[...], p_ref[...])
    x_new, p_new = ref.update_lane(x, p, z_ref[...], m_ref[...])
    xo_ref[...] = x_new
    po_ref[...] = p_new


def lane_spec(rows: int, block_b: int):
    """BlockSpec pinning the sublane extent and tiling only lanes (shared
    with ``kernels.frame``)."""
    return pl.BlockSpec((rows, block_b), lambda i: (0, i))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def predict(x, p, *, block_b: int = DEFAULT_BLOCK_B, interpret: bool = False):
    """``x [7, B]``, ``p [49, B]`` -> predicted ``(x, p)``. B % block_b == 0."""
    b = x.shape[-1]
    assert b % block_b == 0, (b, block_b)
    return pl.pallas_call(
        _predict_kernel,
        grid=(b // block_b,),
        in_specs=[lane_spec(7, block_b), lane_spec(49, block_b)],
        out_specs=[lane_spec(7, block_b), lane_spec(49, block_b)],
        out_shape=[jax.ShapeDtypeStruct((7, b), x.dtype),
                   jax.ShapeDtypeStruct((49, b), p.dtype)],
        interpret=interpret,
    )(x, p)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def update(x, p, z, mask, *, block_b: int = DEFAULT_BLOCK_B,
           interpret: bool = False):
    """Masked update. ``x [7,B]``, ``p [49,B]``, ``z [4,B]``, ``mask [1,B]``."""
    b = x.shape[-1]
    assert b % block_b == 0, (b, block_b)
    specs = [lane_spec(7, block_b), lane_spec(49, block_b),
             lane_spec(4, block_b), lane_spec(1, block_b)]
    return pl.pallas_call(
        _update_kernel,
        grid=(b // block_b,),
        in_specs=specs,
        out_specs=[lane_spec(7, block_b), lane_spec(49, block_b)],
        out_shape=[jax.ShapeDtypeStruct((7, b), x.dtype),
                   jax.ShapeDtypeStruct((49, b), p.dtype)],
        interpret=interpret,
    )(x, p, z, mask)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fused_step(x, p, z, mask, *, block_b: int = DEFAULT_BLOCK_B,
               interpret: bool = False):
    """Predict + masked update in a single VMEM residency."""
    b = x.shape[-1]
    assert b % block_b == 0, (b, block_b)
    specs = [lane_spec(7, block_b), lane_spec(49, block_b),
             lane_spec(4, block_b), lane_spec(1, block_b)]
    return pl.pallas_call(
        _step_kernel,
        grid=(b // block_b,),
        in_specs=specs,
        out_specs=[lane_spec(7, block_b), lane_spec(49, block_b)],
        out_shape=[jax.ShapeDtypeStruct((7, b), x.dtype),
                   jax.ShapeDtypeStruct((49, b), p.dtype)],
        interpret=interpret,
    )(x, p, z, mask)
