"""Batched IoU cost-matrix kernel (Pallas TPU).

Computes the ``[D, T]`` IoU matrix for *every stream in a lane block at
once*: inputs are lane-layout boxes ``det [D, 4, B]`` / ``trk [T, 4, B]``
and the output is ``[D, T, B]``.  The D*T pair loop is unrolled at trace
time (D, T <= ~16 per paper Table I); each pair costs ~12 VPU ops over the
full lane block — the cost matrix for 512 streams is produced in one pass.

VMEM per grid step at block_b=512, D=T=16: (16*4 + 16*4 + 256) * 512 * 4B
≈ 768 KiB.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_B = 512


def _iou_kernel(det_ref, trk_ref, out_ref):
    out_ref[...] = ref.iou_lane(det_ref[...], trk_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def iou_cost(det, trk, *, block_b: int = DEFAULT_BLOCK_B,
             interpret: bool = False):
    """``det [D, 4, B]``, ``trk [T, 4, B]`` -> IoU ``[D, T, B]``."""
    d, _, b = det.shape
    t = trk.shape[0]
    assert b % block_b == 0, (b, block_b)
    return pl.pallas_call(
        _iou_kernel,
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec((d, 4, block_b), lambda i: (0, 0, i)),
                  pl.BlockSpec((t, 4, block_b), lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((d, t, block_b), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((d, t, b), det.dtype),
        interpret=interpret,
    )(det, trk)
