"""Chunk-resident SORT megakernel (Pallas TPU) — one dispatch per CHUNK.

``kernels.frame.fused_frame`` already collapsed each frame to a single
``pallas_call``, but the serving scheduler still dispatches it F times per
chunk from a ``lax.scan``: F kernel launches, and 2x F HBM round-trips for
the ``[49, B]`` covariance block that each launch reads and writes.  With
the paper's extremely small matrices (7x7 state, tiny IoU grids) that
per-launch overhead *is* the cost — so this kernel moves the frame loop
itself inside the ``pallas_call`` (DESIGN.md §9).

Structure: the grid is ``(S // block_s, F)`` with the frame axis as the
**minor** (fastest, sequential) dimension, i.e. an in-kernel frame loop
per stream block.

* **Lane-resident state** (``ref.ChunkState``: means, covariances, the
  int32 lifecycle fields) lives in *revisited output blocks* — their index
  maps are constant over ``f``, so Pallas keeps the block in VMEM across
  all F frames and writes it back to HBM once per stream block, not once
  per frame.  ``@pl.when(f == 0)`` seeds them from the input state refs.
* **Per-frame operands** — the chunk's detections ``[F, D, 4, S]``, det
  masks ``[F, D, S]``, ``stream_active``/``reset`` ``[F, 1, S]``, and the
  optional precomputed ``trk_to_det [F, T, S]`` — use frame-indexed
  BlockSpecs (leading ``None`` squeezes the frame axis), so the standard
  Pallas input pipeline double-buffers frame ``f+1``'s slabs in while
  frame ``f`` computes.
* **Per-frame outputs** (boxes/uid/emit/assignment) are frame-indexed the
  same way and stream out as they are produced.

The body is ``ref.step_chunk_lane`` — the exact serving step (masked lane
re-init + fused frame + lifecycle + emit) in kernel-safe vector algebra —
so the megakernel is bit-identical to F per-frame dispatches.

VMEM per grid step at T=D=16, block_s=128: the resident state is ~994
words/lane (x 7x16 + p 49x16 + 6 int slot fields + 2 counters) = ~0.5 MiB
per copy, ~1 MiB with the input seed; per-frame slabs (det+masks+t2d in,
boxes+ids out) are ~113 KiB live x2 for double-buffering, and the largest
intermediate (the [D, T, block_s] IoU) is 128 KiB.  Total < 2 MiB —
crucially **independent of chunk size F**: frames stream through the minor
grid axis, so only HBM staging grows with F (~100 KiB/frame).  That is why
the chunk can be arbitrarily long without revisiting the §2.3 budget.

Association (DESIGN.md §6): greedy runs fully in-kernel (masked argmax
rounds are vector algebra).  The Hungarian path keeps PR 3's split,
generalized to chunks: its data-dependent JV augmenting paths stay in a
jitted jnp pre-pass (``kernels.ops.chunk_step``) and this kernel consumes
the precomputed per-frame assignment operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .frame import DEFAULT_BLOCK_S

_N_STATE = len(ref.ChunkState._fields)


def _chunk_kernel(*refs, iou_threshold: float, max_age: int, min_hits: int,
                  assoc: str, has_assoc: bool, has_class: bool,
                  has_embed: bool, cost, num_classes: int):
    # `embed` is ChunkState's LAST field; when the cost has no appearance
    # term the zero-size [0, T, S] leaf is dropped from the operand list
    # (Pallas rejects zero-size blocks) and reconstituted as a dummy here.
    n_state = _N_STATE - (0 if has_embed else 1)
    refs = list(refs)
    st_in = refs[:n_state]
    k = n_state
    det_ref, dm_ref, act_ref, rst_ref = refs[k:k + 4]
    k += 4
    t2d_ref = refs[k] if has_assoc else None
    k += int(has_assoc)
    dc_ref = refs[k] if has_class else None
    k += int(has_class)
    de_ref = refs[k] if has_embed else None
    k += int(has_embed)
    st_out = refs[k:k + n_state]
    (boxes_ref, uid_ref, emit_ref, t2d_out_ref, md_ref,
     cls_ref) = refs[k + n_state:]

    f = pl.program_id(1)

    @pl.when(f == 0)
    def _seed_state():  # revisited blocks start as garbage; seed once
        for i_ref, o_ref in zip(st_in, st_out):
            o_ref[...] = i_ref[...]

    leaves = [r[...] for r in st_out]
    if not has_embed:
        t_dim, bs = leaves[2].shape          # alive [T, block_s]
        leaves.append(jnp.zeros((0, t_dim, bs), leaves[0].dtype))
    state = ref.ChunkState(*leaves)
    state, outs = ref.step_chunk_lane(
        state, det_ref[...], dm_ref[...], act_ref[...], rst_ref[...],
        None if t2d_ref is None else t2d_ref[...],
        None if dc_ref is None else dc_ref[...],
        None if de_ref is None else de_ref[...],
        iou_threshold=iou_threshold, max_age=max_age, min_hits=min_hits,
        assoc=assoc, cost=cost, num_classes=num_classes)
    for o_ref, leaf in zip(st_out, state):   # embed leaf skipped if dropped
        o_ref[...] = leaf
    boxes_ref[...] = outs.boxes
    uid_ref[...] = outs.uid
    emit_ref[...] = outs.emit.astype(jnp.int32)
    t2d_out_ref[...] = outs.trk_to_det
    md_ref[...] = outs.matched_det.astype(jnp.int32)
    cls_ref[...] = outs.cls


@functools.partial(jax.jit, static_argnames=("iou_threshold", "max_age",
                                             "min_hits", "assoc", "block_s",
                                             "interpret", "cost",
                                             "num_classes"))
def fused_chunk(state, det, det_mask, active, reset, trk_to_det=None,
                det_class=None, det_embed=None, *,
                iou_threshold: float = 0.3, max_age: int = 1,
                min_hits: int = 3, assoc: str = "greedy",
                cost=None, num_classes: int = 1,
                block_s: int = DEFAULT_BLOCK_S, interpret: bool = False):
    """F serving steps for every stream in a single dispatch.

    ``state`` is a :class:`repro.kernels.ref.ChunkState` (``S % block_s
    == 0``); per-frame operands are ``det [F, D, 4, S]`` xyxy, ``det_mask
    [F, D, S]`` 0/1 float, ``active [F, 1, S]`` 0/1 float, ``reset
    [F, 1, S]`` 0/1 int, optional precomputed ``trk_to_det [F, T, S]``
    int32 (the fused-Hungarian path; with it the in-kernel association is
    skipped — ``assoc`` then only documents intent).

    ``det_class [F, D, S] int32`` / ``det_embed [F, D, E, S]`` (optional)
    are the pluggable-cost operands (DESIGN.md §10), frame-indexed slabs
    exactly like ``det``; ``cost`` (``core.cost.CostSpec``, static) and
    ``num_classes`` configure the in-kernel score/gate.  The per-track
    embedding block rides in the resident state only when the cost has an
    appearance term — a zero-size ``embed`` leaf is dropped from the
    Pallas operand list and passed through unchanged.

    Returns ``(ChunkState, ChunkOuts)`` with outputs stacked ``[F, ...]``
    (``emit``/``matched_det`` as int32 0/1 — the kernel ABI is numeric;
    ``kernels.ops.chunk_step`` restores bool).
    """
    t, s = state.alive.shape
    f, d = det.shape[0], det.shape[1]
    e = state.embed.shape[0]
    has_embed = e > 0
    has_class = det_class is not None
    assert s % block_s == 0, (s, block_s)
    if has_embed and det_embed is None:
        raise ValueError("state carries an embed block but det_embed is "
                         "missing (cost.embed_dim > 0 needs per-frame "
                         "detection embeddings)")
    if assoc == "hungarian" and trk_to_det is None:
        raise ValueError(
            "the Hungarian megakernel path needs the precomputed trk_to_det"
            " operand (kernels.ops.chunk_step builds it); JV augmenting"
            " paths don't run inside the kernel (DESIGN.md §6/§9)")

    def resident(*dims):
        """State block: constant over the frame axis -> VMEM-revisited."""
        return pl.BlockSpec(dims + (block_s,),
                            lambda i, fr: (0,) * len(dims) + (i,))

    def per_frame(*dims):
        """Frame-f slab: leading None squeezes the frame axis; the index
        map walks it, so the pipeline double-buffers frame f+1's DMA."""
        return pl.BlockSpec((None,) + dims + (block_s,),
                            lambda i, fr: (fr,) + (0,) * len(dims) + (i,))

    # zero-size embed leaf: dropped from the kernel operand/output lists
    # (Pallas rejects zero-size blocks) and passed through unchanged
    state_leaves = list(state)[:-1] if not has_embed else list(state)
    n_state = len(state_leaves)
    state_specs = [resident(7, t), resident(49, t)] + [resident(t)] * 7 + \
                  [resident(1), resident(1)]
    if has_embed:
        state_specs.append(resident(e, t))
    operands = state_leaves + [det, det_mask, active, reset]
    in_specs = state_specs + [per_frame(d, 4), per_frame(d),
                              per_frame(1), per_frame(1)]
    if trk_to_det is not None:
        operands.append(trk_to_det)
        in_specs.append(per_frame(t))
    if has_class:
        operands.append(det_class)
        in_specs.append(per_frame(d))
    if has_embed:
        operands.append(det_embed)
        in_specs.append(per_frame(d, e))

    state_shapes = [jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
                    for leaf in state_leaves]
    out_shapes = state_shapes + [
        jax.ShapeDtypeStruct((f, t, 4, s), state.x.dtype),   # boxes
        jax.ShapeDtypeStruct((f, t, s), jnp.int32),          # uid
        jax.ShapeDtypeStruct((f, t, s), jnp.int32),          # emit
        jax.ShapeDtypeStruct((f, t, s), jnp.int32),          # trk_to_det
        jax.ShapeDtypeStruct((f, d, s), jnp.int32),          # matched_det
        jax.ShapeDtypeStruct((f, t, s), jnp.int32),          # cls
    ]
    out_specs = state_specs + [per_frame(t, 4), per_frame(t), per_frame(t),
                               per_frame(t), per_frame(d), per_frame(t)]

    results = pl.pallas_call(
        functools.partial(_chunk_kernel, iou_threshold=iou_threshold,
                          max_age=max_age, min_hits=min_hits, assoc=assoc,
                          has_assoc=trk_to_det is not None,
                          has_class=has_class, has_embed=has_embed,
                          cost=cost, num_classes=num_classes),
        grid=(s // block_s, f),       # frame axis minor: in-kernel loop
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*operands)
    out_state_leaves = list(results[:n_state])
    if not has_embed:
        out_state_leaves.append(state.embed)     # pass-through [0, T, S]
    return (ref.ChunkState(*out_state_leaves),
            ref.ChunkOuts(*results[n_state:]))
