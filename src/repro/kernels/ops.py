"""Public jit'd wrappers for the Pallas kernels.

Converts between the engine's natural layout (``x [S, T, 7]``,
``p [S, T, 7, 7]``) and the kernels' lane layout (batch on lanes), pads the
flattened tracker batch to the lane-block size, and dispatches:

* TPU backend  -> compiled Pallas kernel,
* anything else -> the same kernel in ``interpret=True`` (bit-identical
  semantics, Python-evaluated) or the pure-jnp oracle for speed.

``engine_fns()`` returns drop-in ``predict_fn`` / ``update_fn`` / ``iou_fn``
for :class:`repro.core.sort.SortEngine`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import chunk as _chunk
from . import frame as _frame
from . import iou_cost as _iou_kernel
from . import kalman_fused as _kalman
from . import ref

__all__ = ["predict", "update", "iou", "frame_step", "chunk_step",
           "engine_fns", "to_lane", "from_lane"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_b(b: int, block_b: int) -> int:
    return -(-b // block_b) * block_b


# ---------------------------------------------------------------- layouts
def to_lane(x: jnp.ndarray, p: jnp.ndarray, block_b: int):
    """``x [S,T,7], p [S,T,7,7]`` -> lane layout ``[7,B], [49,B]`` (padded)."""
    s, t = x.shape[0], x.shape[1]
    b = s * t
    bp = _pad_b(b, block_b)
    xl = x.reshape(b, 7).T
    pl_ = p.reshape(b, 49).T
    if bp != b:
        xl = jnp.pad(xl, ((0, 0), (0, bp - b)))
        pl_ = jnp.pad(pl_, ((0, 0), (0, bp - b)),
                      constant_values=1.0)  # keep padded S invertible
    return xl, pl_


def from_lane(xl: jnp.ndarray, pl_: jnp.ndarray, s: int, t: int):
    b = s * t
    return (xl[:, :b].T.reshape(s, t, 7),
            pl_[:, :b].T.reshape(s, t, 7, 7))


# ------------------------------------------------------------------- ops
def predict(x, p, *, block_b: int = _kalman.DEFAULT_BLOCK_B,
            interpret: bool | None = None):
    """Engine-layout predict via the fused kernel."""
    s, t = x.shape[0], x.shape[1]
    xl, pl_ = to_lane(x, p, block_b)
    xl, pl_ = _kalman.predict(xl, pl_, block_b=block_b,
                              interpret=_resolve(interpret))
    return from_lane(xl, pl_, s, t)


def update(x, p, z, mask, *, block_b: int = _kalman.DEFAULT_BLOCK_B,
           interpret: bool | None = None):
    """Engine-layout masked update via the fused kernel.

    ``z [S, T, 4]``, ``mask [S, T]`` bool.
    """
    s, t = x.shape[0], x.shape[1]
    b = s * t
    bp = _pad_b(b, block_b)
    xl, pl_ = to_lane(x, p, block_b)
    zl = jnp.pad(z.reshape(b, 4).T, ((0, 0), (0, bp - b)))
    ml = jnp.pad(mask.reshape(1, b).astype(x.dtype), ((0, 0), (0, bp - b)))
    xl, pl_ = _kalman.update(xl, pl_, zl, ml, block_b=block_b,
                             interpret=_resolve(interpret))
    return from_lane(xl, pl_, s, t)


def iou(det_boxes, trk_boxes, *, block_b: int = _iou_kernel.DEFAULT_BLOCK_B,
        interpret: bool | None = None):
    """``det [S, D, 4]``, ``trk [S, T, 4]`` -> IoU ``[S, D, T]``."""
    s, d = det_boxes.shape[0], det_boxes.shape[1]
    t = trk_boxes.shape[1]
    bp = _pad_b(s, block_b)
    dl = jnp.pad(det_boxes.transpose(1, 2, 0), ((0, 0), (0, 0), (0, bp - s)))
    tl = jnp.pad(trk_boxes.transpose(1, 2, 0), ((0, 0), (0, 0), (0, bp - s)))
    out = _iou_kernel.iou_cost(dl, tl, block_b=block_b,
                               interpret=_resolve(interpret))
    return out[:, :, :s].transpose(2, 0, 1)


def frame_step(x, p, det, det_mask, alive, stream_active=None,
               det_class=None, trk_cls=None, det_embed=None,
               trk_embed=None, *, iou_threshold: float = 0.3,
               cost=None, num_classes: int = 1,
               block_s: int = _frame.DEFAULT_BLOCK_S,
               mode: str = "auto", assoc: str = "greedy"):
    """Single-dispatch fused frame (predict -> IoU -> assign -> update).

    All operands already in the persistent lane layout (``x [7, T, S]``,
    ``p [49, T, S]``, ``det [D, 4, S]``, masks ``[*, S]`` 0/1 float) —
    no per-call conversion.  ``stream_active [1, S]`` 0/1 float (optional)
    marks which lanes carry a live ragged sequence this frame; inactive
    lanes are exact in-kernel no-ops (DESIGN.md §3).

    ``assoc`` (DESIGN.md §6): ``"greedy"`` matches inside the kernel;
    ``"hungarian"`` (the paper's algorithm) solves the lane-batched JV
    assignment as a jitted jnp stage *before* the kernel —
    :func:`_hungarian_stage` recomputes the cheap predicted means/IoU,
    gates, and hands the kernel a precomputed ``trk_to_det``, so the
    ``[49, B]`` covariance still enters exactly one ``pallas_call`` per
    frame (no host round-trip, no state re-dispatch).

    ``cost`` (``core.cost.CostSpec``) + ``num_classes`` plus their lane
    operands — ``det_class [D, S]`` / ``trk_cls [T, S]`` int32,
    ``det_embed [D, E, S]`` / ``trk_embed [E, T, S]`` — activate the
    pluggable association score/gate (DESIGN.md §10) on every backend.

    ``mode``:

    * ``"auto"``   — compiled Pallas kernel on TPU, lane-layout oracle
      elsewhere (interpret mode pays a Python-per-grid-step tax that would
      dwarf the frame; the oracle is the same math).
    * ``"pallas"`` / ``"interpret"`` / ``"ref"`` — force a backend.
    """
    if assoc not in ("greedy", "hungarian"):
        raise ValueError(f"unknown assoc {assoc!r}")
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    cost_kw = dict(det_class=det_class, trk_cls=trk_cls,
                   det_embed=det_embed, trk_embed=trk_embed,
                   cost=cost, num_classes=num_classes)
    if mode == "ref":
        x, p, t2d, md = ref.frame_lane(x, p, det, det_mask, alive,
                                       iou_threshold, active=stream_active,
                                       assoc=assoc, **cost_kw)
        return x, p, t2d, md
    t2d_pre = (None if assoc != "hungarian"
               else _hungarian_stage(x, p, det, det_mask, alive,
                                     stream_active, iou_threshold,
                                     **cost_kw))
    if t2d_pre is not None:
        # association decided in the pre-pass; the kernel only gathers by
        # assignment, so the cost operands need not enter VMEM
        cost_kw = {}
    x, p, t2d, md = _frame.fused_frame(
        x, p, det, det_mask, alive, stream_active, t2d_pre,
        iou_threshold=iou_threshold,
        block_s=block_s, interpret=(mode == "interpret"), **cost_kw)
    return x, p, t2d, md > 0


def chunk_step(state, det, det_mask, active, reset,
               det_class=None, det_embed=None, *,
               iou_threshold: float = 0.3, max_age: int = 1,
               min_hits: int = 3, cost=None, num_classes: int = 1,
               block_s: int = _frame.DEFAULT_BLOCK_S,
               mode: str = "auto", assoc: str = "greedy"):
    """Whole-chunk fused serving step: F frames in ONE dispatch
    (DESIGN.md §9) — the chunk-granularity sibling of :func:`frame_step`.

    Operands in the chunk lane layout: ``state`` is a
    ``kernels.ref.ChunkState``; ``det [F, D, 4, S]`` xyxy, ``det_mask
    [F, D, S]`` 0/1 float, ``active [F, 1, S]`` 0/1 float, ``reset
    [F, 1, S]`` 0/1 int — the scheduler's whole planned chunk, staged up
    front so the kernel's input pipeline can double-buffer the per-frame
    slabs.  Returns ``(ChunkState, ChunkOuts)`` with bool ``emit`` /
    ``matched_det``.

    ``assoc`` (DESIGN.md §6/§9): ``"greedy"`` matches fully in-kernel.
    ``"hungarian"`` keeps the pattern :func:`frame_step` proved, lifted to
    chunk scope: the lane-batched JV solves run as a jitted jnp pre-pass
    whose precomputed ``[F, T, S]`` ``trk_to_det`` enters the kernel as
    one extra operand.  Assignments at frame ``f`` depend on the state at
    frame ``f``, so the pre-pass must *replay the chunk's state evolution*
    — it is the chunk oracle itself (``ref.chunk_lane``), fused by jit
    into the same device program as the ``pallas_call`` that consumes it.

    ``mode`` as in :func:`frame_step`: ``"auto"`` compiles the megakernel
    on TPU and runs the chunk oracle elsewhere (on the oracle path the
    Hungarian pre-pass result IS the answer — nothing runs twice);
    ``"pallas"`` / ``"interpret"`` / ``"ref"`` force a backend.
    """
    if assoc not in ("greedy", "hungarian"):
        raise ValueError(f"unknown assoc {assoc!r}")
    if mode == "auto":
        mode = "pallas" if _on_tpu() else "ref"
    kw = dict(iou_threshold=iou_threshold, max_age=max_age,
              min_hits=min_hits, cost=cost, num_classes=num_classes)
    if mode == "ref":
        return ref.chunk_lane(state, det, det_mask, active, reset,
                              det_class=det_class, det_embed=det_embed,
                              assoc=assoc, **kw)
    t2d_pre = None
    if assoc == "hungarian":
        _, pre = ref.chunk_lane(state, det, det_mask, active, reset,
                                det_class=det_class, det_embed=det_embed,
                                assoc="hungarian", **kw)
        t2d_pre = pre.trk_to_det
    new_state, outs = _chunk.fused_chunk(
        state, det, det_mask, active, reset, t2d_pre,
        det_class=det_class, det_embed=det_embed, assoc=assoc,
        block_s=block_s, interpret=(mode == "interpret"), **kw)
    return new_state, outs._replace(emit=outs.emit > 0,
                                    matched_det=outs.matched_det > 0)


def _hungarian_stage(x, p, det, det_mask, alive, stream_active,
                     iou_threshold: float, det_class=None, trk_cls=None,
                     det_embed=None, trk_embed=None, cost=None,
                     num_classes: int = 1):
    """The fused path's lane-batched JV association stage (DESIGN.md §6).

    Recomputes the predicted means (7 rows of adds — free next to the
    49-row covariance, which never leaves the kernel), builds the
    ``[D, T, S]`` IoU, and solves + gates one tiny assignment per lane
    with ``core.association.associate_lane``.  Pure jnp, so under jit it
    fuses into the same device program as the ``pallas_call`` that
    consumes its output: no host round-trip between solve and update.

    A Mahalanobis-gated ``cost`` additionally needs the predicted
    covariance's 4x4 block: ``ref.predict_cov4_lane`` recomputes it from
    the pre-predict ``p`` with the exact accumulation order of the
    in-kernel predict, so the gate decides on the same floats the kernel
    would see (the dispatch-mode bit-parity contract).
    """
    from repro.core import cost as cost_mod
    from repro.core.association import associate_lane

    dm = det_mask > 0
    if stream_active is not None:
        dm = dm & (stream_active > 0)
    x_pred = ref.predict_mean_lane(x)                             # [7, T, S]
    trk_boxes = ref.z_to_xyxy_lane(x_pred[:4])                    # [T, 4, S]
    iou = ref.iou_lane(det, trk_boxes)                            # [D, T, S]
    score = feasible = None
    if cost is not None and (cost_mod.needs_score(cost)
                             or cost_mod.needs_feasible(cost, num_classes)):
        score, feasible = cost_mod.score_and_feasible_lane(
            iou, cost, num_classes=num_classes,
            det_class=det_class, trk_cls=trk_cls,
            det_embed=det_embed, trk_embed=trk_embed,
            z_det=ref.xyxy_to_z_lane(det) if cost.uses_maha else None,
            x_pred=x_pred,
            p4_pred=ref.predict_cov4_lane(p) if cost.uses_maha else None)
    t2d, _ = associate_lane(iou, dm, alive > 0, iou_threshold,
                            score=score, feasible=feasible)
    return t2d


def _resolve(interpret: bool | None) -> bool:
    return (not _on_tpu()) if interpret is None else interpret


# ------------------------------------------------------------ engine glue
def engine_fns(block_b: int | None = None, use_ref: bool = False):
    """Kernel-backed ``(predict_fn, update_fn, iou_fn)`` for SortEngine.

    ``use_ref=True`` bypasses pallas_call and uses the lane-layout oracle —
    the fast path on CPU (interpret mode pays a Python-per-grid-step tax)
    with identical numerics.
    """
    kb = block_b or _kalman.DEFAULT_BLOCK_B
    ib = block_b or _iou_kernel.DEFAULT_BLOCK_B

    if use_ref:
        def predict_fn(x, p):
            s, t = x.shape[0], x.shape[1]
            xl, pl_ = to_lane(x, p, kb)
            return from_lane(*ref.predict_lane(xl, pl_), s, t)

        def update_fn(x, p, z, m):
            s, t = x.shape[0], x.shape[1]
            b, bp = s * t, _pad_b(s * t, kb)
            xl, pl_ = to_lane(x, p, kb)
            zl = jnp.pad(z.reshape(b, 4).T, ((0, 0), (0, bp - b)))
            ml = jnp.pad(m.reshape(1, b).astype(x.dtype), ((0, 0), (0, bp - b)))
            return from_lane(*ref.update_lane(xl, pl_, zl, ml), s, t)

        def iou_fn(a, b_):
            s = a.shape[0]
            return ref.iou_lane(a.transpose(1, 2, 0),
                                b_.transpose(1, 2, 0)).transpose(2, 0, 1)
        return predict_fn, update_fn, iou_fn

    return (functools.partial(predict, block_b=kb),
            functools.partial(update, block_b=kb),
            functools.partial(iou, block_b=ib))
