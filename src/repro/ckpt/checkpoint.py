"""Sharded checkpointing with atomic commit, async writes, elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, logical specs
        shard_<host>.npz   this host's param/opt leaves (flattened paths)
    <dir>/LATEST           committed step pointer (written last — atomicity)

Fault-tolerance contract (DESIGN.md §9):

* a checkpoint is visible only after ``LATEST`` is atomically renamed in —
  a host dying mid-write never corrupts the restore point;
* ``restore`` takes an *optional* mesh: leaves are re-sharded from the
  logical specs recorded at save time, so a job restarted on a different
  topology (e.g. one pod lost, 2x16x16 -> 16x16) resumes without
  conversion — elastic restart;
* ``CheckpointManager`` writes in a background thread (training never
  blocks on disk) and keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading

import jax
import numpy as np


def _flatten(tree):
    # jax.tree.flatten_with_path only exists on newer jax; the tree_util
    # spelling works on every version this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, tree, logical_specs=None,
         host_id: int = 0):
    """Write one checkpoint synchronously. Safe against partial writes."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=_ensure(ckpt_dir))
    try:
        keys, leaves, _ = _flatten(tree)
        arrays = {k: np.asarray(l) for k, l in zip(keys, leaves)}
        np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": [list(np.shape(a)) for a in arrays.values()],
            "dtypes": [str(a.dtype) for a in arrays.values()],
            "specs": _specs_json(logical_specs, tree),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp, step_dir)
        _commit_latest(ckpt_dir, step)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as fh:
        return int(fh.read().strip())


def restore(ckpt_dir: str, tree_like, step: int | None = None,
            mesh=None, pspecs=None, host_id: int = 0):
    """Load a checkpoint into the structure of ``tree_like``.

    With ``mesh``+``pspecs``, leaves are placed as NamedSharding arrays for
    the *current* topology (elastic restart); otherwise plain host arrays.
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    data = np.load(os.path.join(step_dir, f"shard_{host_id:05d}.npz"))
    keys, leaves, treedef = _flatten(tree_like)
    out = []
    flat_specs = None
    if pspecs is not None:
        flat_specs = treedef.flatten_up_to(pspecs)
    for i, (k, like) in enumerate(zip(keys, leaves)):
        arr = data[k]
        assert tuple(arr.shape) == tuple(np.shape(like)), \
            f"shape mismatch for {k}: {arr.shape} vs {np.shape(like)}"
        want = np.dtype(getattr(like, "dtype", arr.dtype))
        if arr.dtype != want and arr.dtype.itemsize == want.itemsize:
            # npz stores ml_dtypes (bfloat16, fp8) as raw void — re-view
            arr = arr.view(want)
        if mesh is not None and flat_specs is not None:
            from jax.sharding import NamedSharding
            arr = jax.device_put(arr, NamedSharding(mesh, flat_specs[i]))
        out.append(arr)
    return jax.tree.unflatten(treedef, out), step


class CheckpointManager:
    """Async background writer + retention policy."""

    def __init__(self, ckpt_dir: str, keep: int = 3, logical_specs=None):
        self.ckpt_dir = _ensure(ckpt_dir)
        self.keep = keep
        self.logical_specs = logical_specs
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree):
        self.wait()  # one in-flight write at a time
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def _write(self, step, host_tree):
        save(self.ckpt_dir, step, host_tree, self.logical_specs)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)


def _commit_latest(ckpt_dir, step):
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as fh:
        fh.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def _ensure(d):
    os.makedirs(d, exist_ok=True)
    return d


def _specs_json(logical_specs, tree):
    """Recursively JSON-encode the logical-spec tree (tuples of axis names)."""
    if logical_specs is None:
        return None

    def enc(node):
        if isinstance(node, tuple):
            return [str(a) if a is not None else None for a in node]
        if isinstance(node, dict):
            return {k: enc(v) for k, v in node.items()}
        if isinstance(node, (list,)):
            return [enc(v) for v in node]
        return None

    return enc(logical_specs)
