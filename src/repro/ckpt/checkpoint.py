"""Sharded checkpointing with atomic commit, async writes, elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, logical specs
        shard_<host>.npz   this host's param/opt leaves (flattened paths)
    <dir>/LATEST           committed step pointer (written last — atomicity)

Fault-tolerance contract (DESIGN.md §11):

* a checkpoint is visible only after ``LATEST`` is atomically renamed in —
  a host dying mid-write never corrupts the restore point;
* a stale ``LATEST`` (its step directory deleted or incomplete) never
  strands a restore: ``restore``/``restore_flat`` fall back to the newest
  *committed* step — a directory whose ``manifest.json`` exists;
* ``restore`` takes an *optional* mesh: leaves are re-sharded from the
  logical specs recorded at save time, so a job restarted on a different
  topology (e.g. one pod lost, 2x16x16 -> 16x16) resumes without
  conversion — elastic restart; requested leaf paths are validated
  against the manifest first, so a topology mismatch raises a
  ``ValueError`` naming the missing/extra paths instead of a bare
  ``KeyError``;
* ``CheckpointManager`` writes in a background thread (training never
  blocks on disk) and keeps the newest ``keep`` checkpoints.  A failed
  background write is **never silent**: the exception is recorded and
  re-raised on the next ``wait()``/``save_async()`` call.  Temp dirs
  leaked by a writer killed between ``mkdtemp`` and ``os.replace`` are
  swept once they go stale.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time

import jax
import numpy as np


def flatten_with_paths(tree):
    """``(keys, leaves, treedef)`` with the exact "/"-joined path strings
    ``save``/``restore`` name leaves by — public so callers serializing
    data-dependent trees (the serving checkpoint, DESIGN.md §11) can
    address leaves consistently."""
    # jax.tree.flatten_with_path only exists on newer jax; the tree_util
    # spelling works on every version this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], jax.tree.structure(tree)


_flatten = flatten_with_paths


def save(ckpt_dir: str, step: int, tree, logical_specs=None,
         host_id: int = 0):
    """Write one checkpoint synchronously. Safe against partial writes."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=_ensure(ckpt_dir))
    try:
        keys, leaves, _ = _flatten(tree)
        arrays = {k: np.asarray(l) for k, l in zip(keys, leaves)}
        np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": [list(np.shape(a)) for a in arrays.values()],
            "dtypes": [str(a.dtype) for a in arrays.values()],
            "specs": _specs_json(logical_specs, tree),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp, step_dir)
        _commit_latest(ckpt_dir, step)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as fh:
        return int(fh.read().strip())


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def committed_steps(ckpt_dir: str) -> list[int]:
    """Every step with a *complete* directory (``manifest.json`` present),
    ascending.  ``LATEST`` is the commit pointer, but a crash can leave it
    stale (its target GC'd or never finished) — this is ground truth."""
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    steps = []
    for d in names:
        if not d.startswith("step_"):
            continue
        try:
            s = int(d.split("_", 1)[1])
        except ValueError:
            continue
        if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(s)
    return sorted(steps)


def _resolve_step(ckpt_dir: str, step: int | None) -> int:
    """An explicit ``step`` is trusted; ``None`` resolves to ``LATEST`` if
    its directory is complete, else to the newest committed step (a killed
    writer must always land the restore on the last *committed* step)."""
    if step is not None:
        return step
    step = latest_step(ckpt_dir)
    if step is not None and os.path.exists(
            os.path.join(_step_dir(ckpt_dir, step), "manifest.json")):
        return step
    committed = committed_steps(ckpt_dir)
    if not committed:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    return committed[-1]


def _load_manifest(step_dir: str) -> dict:
    with open(os.path.join(step_dir, "manifest.json")) as fh:
        return json.load(fh)


def _validate_keys(step_dir: str, requested: list[str]) -> None:
    """Requested leaf paths must all exist in the shard — checked against
    ``manifest.json`` up front so an elastic-restart topology mismatch
    raises a diagnosable ``ValueError`` naming the offending paths, not a
    bare ``KeyError`` from the npz lookup."""
    stored = set(_load_manifest(step_dir)["keys"])
    missing = [k for k in requested if k not in stored]
    if missing:
        extra = sorted(stored - set(requested))
        raise ValueError(
            f"checkpoint {step_dir} does not match the requested tree: "
            f"missing leaf path(s) {missing}; checkpoint-only path(s) "
            f"{extra}.  (restoring onto a different tree topology than "
            f"was saved?)")


def restore_flat(ckpt_dir: str, step: int | None = None,
                 host_id: int = 0) -> tuple[dict, int]:
    """Every stored leaf of one committed checkpoint as a flat
    ``{path: np.ndarray}`` dict, plus the resolved step.

    For callers whose tree *structure* is data-dependent and therefore
    unknowable before the load (the serving checkpoint's per-sequence
    buffers, DESIGN.md §11) — the manifest, not a ``tree_like``, defines
    what comes back.  Leaves are materialized host copies; ml_dtypes
    stored as raw void are NOT re-viewed (callers with such leaves should
    use :func:`restore`).
    """
    step = _resolve_step(ckpt_dir, step)
    path = os.path.join(_step_dir(ckpt_dir, step), f"shard_{host_id:05d}.npz")
    with np.load(path) as data:
        return {k: np.array(data[k]) for k in data.files}, step


def restore(ckpt_dir: str, tree_like, step: int | None = None,
            mesh=None, pspecs=None, host_id: int = 0):
    """Load a checkpoint into the structure of ``tree_like``.

    With ``mesh``+``pspecs``, leaves are placed as NamedSharding arrays for
    the *current* topology (elastic restart); otherwise plain host arrays.
    """
    step = _resolve_step(ckpt_dir, step)
    step_dir = _step_dir(ckpt_dir, step)
    keys, leaves, treedef = _flatten(tree_like)
    _validate_keys(step_dir, keys)
    data = np.load(os.path.join(step_dir, f"shard_{host_id:05d}.npz"))
    out = []
    flat_specs = None
    if pspecs is not None:
        flat_specs = treedef.flatten_up_to(pspecs)
    for i, (k, like) in enumerate(zip(keys, leaves)):
        arr = data[k]
        assert tuple(arr.shape) == tuple(np.shape(like)), \
            f"shape mismatch for {k}: {arr.shape} vs {np.shape(like)}"
        want = np.dtype(getattr(like, "dtype", arr.dtype))
        if arr.dtype != want and arr.dtype.itemsize == want.itemsize:
            # npz stores ml_dtypes (bfloat16, fp8) as raw void — re-view
            arr = arr.view(want)
        if mesh is not None and flat_specs is not None:
            from jax.sharding import NamedSharding
            arr = jax.device_put(arr, NamedSharding(mesh, flat_specs[i]))
        out.append(arr)
    return jax.tree.unflatten(treedef, out), step


class CheckpointManager:
    """Async background writer + retention policy.

    Failure contract: the background thread records any exception from
    ``save()`` and the next ``wait()``/``save_async()`` **re-raises it** —
    a failed write (disk full, permissions, ...) is never mistaken for a
    committed checkpoint.  ``wait()`` must therefore be called before
    trusting that a ``save_async`` landed (e.g. before shutdown).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3, logical_specs=None,
                 stale_tmp_age: float = 3600.0):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep} (keep=0 would "
                             f"GC every checkpoint the moment it commits)")
        self.ckpt_dir = _ensure(ckpt_dir)
        self.keep = keep
        self.logical_specs = logical_specs
        self.stale_tmp_age = stale_tmp_age
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        # a writer killed between mkdtemp and os.replace leaks its temp
        # dir forever (atomic commit never renames it in, and step-dir GC
        # only matches step_*); sweep leftovers from previous incarnations
        # now, and stale ones on every _gc.
        _sweep_stale_tmp(self.ckpt_dir, self.stale_tmp_age)

    def save_async(self, step: int, tree):
        self.wait()  # one in-flight write at a time; raises a prior failure
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def _write(self, step, host_tree):
        try:
            save(self.ckpt_dir, step, host_tree, self.logical_specs)
            self._gc()
        except BaseException as e:  # surfaced by the next wait()/save_async()
            self._error = e

    def wait(self):
        """Join the in-flight write; re-raise its failure, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = []
        for d in os.listdir(self.ckpt_dir):
            if not d.startswith("step_"):
                continue
            try:
                steps.append(int(d.split("_", 1)[1]))
            except ValueError:
                continue  # foreign step_* name: not ours to delete or crash on
        for s in sorted(steps)[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:09d}"),
                          ignore_errors=True)
        _sweep_stale_tmp(self.ckpt_dir, self.stale_tmp_age)


def _sweep_stale_tmp(ckpt_dir: str, max_age: float) -> None:
    """Remove ``.tmp_ckpt_*`` dirs older than ``max_age`` seconds — debris
    of writers killed mid-write.  The age guard keeps a *live* concurrent
    writer's temp dir (same or another process) safe from the sweep."""
    now = time.time()
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return
    for d in names:
        if not d.startswith(".tmp_ckpt_"):
            continue
        p = os.path.join(ckpt_dir, d)
        try:
            age = now - os.path.getmtime(p)
        except OSError:
            continue  # raced with its own writer's os.replace — it's live
        if age >= max_age:
            shutil.rmtree(p, ignore_errors=True)


def _commit_latest(ckpt_dir, step):
    tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(tmp, "w") as fh:
        fh.write(str(step))
    os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))


def _ensure(d):
    os.makedirs(d, exist_ok=True)
    return d


def _specs_json(logical_specs, tree):
    """Recursively JSON-encode the logical-spec tree (tuples of axis names)."""
    if logical_specs is None:
        return None

    def enc(node):
        if isinstance(node, tuple):
            return [str(a) if a is not None else None for a in node]
        if isinstance(node, dict):
            return {k: enc(v) for k, v in node.items()}
        if isinstance(node, (list,)):
            return [enc(v) for v in node]
        return None

    return enc(logical_specs)
