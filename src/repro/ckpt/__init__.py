from .checkpoint import CheckpointManager, restore, save  # noqa: F401
