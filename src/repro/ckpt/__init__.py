from .checkpoint import (CheckpointManager, committed_steps,  # noqa: F401
                         flatten_with_paths, latest_step, restore,
                         restore_flat, save)
