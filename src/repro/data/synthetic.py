"""Synthetic MOT workload generator.

Produces ground-truth multi-object trajectories plus noisy detections with
false positives and dropouts — statistically shaped like the MOT15 sequences
in paper Table I (≤13 simultaneous objects, hundreds of frames), so the
benchmarks can sweep stream counts far beyond the paper's 11 files.

Pure numpy on the host (this is the data pipeline, not the tracker).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    num_frames: int = 200
    max_objects: int = 12           # simultaneous objects cap (Table I max: 13)
    img_w: float = 1920.0
    img_h: float = 1080.0
    mean_size: float = 80.0         # mean box side, px
    speed: float = 8.0              # px/frame
    birth_rate: float = 0.05        # P(new object appears per frame)
    death_rate: float = 0.005       # P(object leaves per frame)
    det_noise: float = 2.0          # detection jitter, px
    miss_rate: float = 0.05         # P(detection dropout)
    fp_rate: float = 0.1            # expected false positives per frame
    seed: int = 0


def generate_scene(cfg: SceneConfig):
    """Simulate one video sequence.

    Returns
    -------
    gt_boxes : float32 ``[F, K, 4]`` xyxy ground truth (K = total objects ever)
    gt_mask  : bool    ``[F, K]`` object present in frame
    det_boxes: float32 ``[F, D, 4]`` noisy detections (padded)
    det_mask : bool    ``[F, D]``
    """
    rng = np.random.default_rng(cfg.seed)
    f = cfg.num_frames

    # --- simulate object lifecycles ---
    tracks = []  # (t_birth, t_death, trajectory [L, 4])
    active = []
    for _ in range(rng.integers(2, max(3, cfg.max_objects // 2 + 1))):
        active.append(_spawn(rng, cfg, 0))
    for t in range(1, f):
        if len(active) < cfg.max_objects and rng.random() < cfg.birth_rate:
            active.append(_spawn(rng, cfg, t))
        survivors = []
        for tr in active:
            if rng.random() < cfg.death_rate:
                tr["t_death"] = t
                tracks.append(tr)
            else:
                _step(tr, cfg)
                survivors.append(tr)
        active = survivors
    for tr in active:
        tr["t_death"] = f
        tracks.append(tr)

    k = len(tracks)
    gt_boxes = np.zeros((f, k, 4), np.float32)
    gt_mask = np.zeros((f, k), bool)
    for i, tr in enumerate(tracks):
        t0, t1 = tr["t_birth"], tr["t_death"]
        traj = np.asarray(tr["traj"][: t1 - t0], np.float32).reshape(-1, 4)
        gt_boxes[t0:t0 + len(traj), i] = traj
        gt_mask[t0:t0 + len(traj), i] = True

    # --- corrupt into detections ---
    d_max = cfg.max_objects + max(2, int(3 * cfg.fp_rate))
    det_boxes = np.zeros((f, d_max, 4), np.float32)
    det_mask = np.zeros((f, d_max), bool)
    for t in range(f):
        dets = []
        for i in range(k):
            if gt_mask[t, i] and rng.random() >= cfg.miss_rate:
                dets.append(gt_boxes[t, i] + rng.normal(0, cfg.det_noise, 4))
        n_fp = rng.poisson(cfg.fp_rate)
        for _ in range(n_fp):
            cx = rng.uniform(0, cfg.img_w)
            cy = rng.uniform(0, cfg.img_h)
            s = rng.uniform(0.5, 1.5) * cfg.mean_size
            dets.append([cx - s / 2, cy - s / 2, cx + s / 2, cy + s / 2])
        rng.shuffle(dets)
        dets = dets[:d_max]
        if dets:
            det_boxes[t, : len(dets)] = np.asarray(dets, np.float32)
            det_mask[t, : len(dets)] = True
    return gt_boxes, gt_mask, det_boxes, det_mask


def _spawn(rng, cfg, t):
    w = max(8.0, rng.normal(cfg.mean_size, cfg.mean_size / 4))
    h = max(8.0, rng.normal(cfg.mean_size * 2, cfg.mean_size / 3))  # pedestrian-ish
    cx = rng.uniform(w, cfg.img_w - w)
    cy = rng.uniform(h, cfg.img_h - h)
    vx, vy = rng.normal(0, cfg.speed, 2)
    box = [cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2]
    return {"t_birth": t, "t_death": None, "traj": [box],
            "v": (vx, vy), "wh": (w, h), "c": (cx, cy)}


def _step(tr, cfg):
    vx, vy = tr["v"]
    cx, cy = tr["c"]
    w, h = tr["wh"]
    cx = float(np.clip(cx + vx, w / 2, cfg.img_w - w / 2))
    cy = float(np.clip(cy + vy, h / 2, cfg.img_h - h / 2))
    tr["c"] = (cx, cy)
    tr["traj"].append([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2])


def generate_multiclass_scene(cfg: SceneConfig, num_classes: int = 3,
                              embed_dim: int = 4):
    """Multi-class variant of :func:`generate_scene` (DESIGN.md §10).

    Every ground-truth object carries a **class-stable** label (drawn once
    at birth, never changes along the trajectory) and an identity-coded
    one-hot appearance embedding (``eye[k % embed_dim]`` — dot products
    are exactly 0 or 1, so f32/f64 evaluators agree bit for bit).  True
    detections inherit their object's class/embedding; false positives
    get random ones.

    Returns ``(gt_boxes [F, K, 4], gt_mask [F, K], gt_class [K] int32,
    det_boxes [F, D, 4], det_mask [F, D], det_class [F, D] int32,
    det_embed [F, D, E] float32)``.
    """
    gt_boxes, gt_mask, _, _ = generate_scene(cfg)
    rng = np.random.default_rng(cfg.seed + 7919)  # decouple from geometry
    f, k = gt_mask.shape
    gt_class = rng.integers(0, num_classes, size=k).astype(np.int32)
    eye = np.eye(embed_dim, dtype=np.float32)
    gt_embed = eye[np.arange(k) % embed_dim]
    d_max = cfg.max_objects + max(2, int(3 * cfg.fp_rate))
    det_boxes = np.zeros((f, d_max, 4), np.float32)
    det_mask = np.zeros((f, d_max), bool)
    det_class = np.zeros((f, d_max), np.int32)
    det_embed = np.zeros((f, d_max, embed_dim), np.float32)
    for t in range(f):
        rows = []
        for i in range(k):
            if gt_mask[t, i] and rng.random() >= cfg.miss_rate:
                box = (gt_boxes[t, i]
                       + rng.normal(0, cfg.det_noise, 4)).astype(np.float32)
                rows.append((box, int(gt_class[i]), gt_embed[i]))
        for _ in range(rng.poisson(cfg.fp_rate)):
            cx = rng.uniform(0, cfg.img_w)
            cy = rng.uniform(0, cfg.img_h)
            s = rng.uniform(0.5, 1.5) * cfg.mean_size
            rows.append((np.array([cx - s / 2, cy - s / 2,
                                   cx + s / 2, cy + s / 2], np.float32),
                         int(rng.integers(num_classes)),
                         eye[int(rng.integers(embed_dim))]))
        rng.shuffle(rows)
        for di, (box, c, e) in enumerate(rows[:d_max]):
            det_boxes[t, di] = box
            det_mask[t, di] = True
            det_class[t, di] = c
            det_embed[t, di] = e
    return (gt_boxes, gt_mask, gt_class,
            det_boxes, det_mask, det_class, det_embed)


def generate_crossing_scene(num_frames: int = 40, num_objects: int = 4,
                            num_classes: int = 2, embed_dim: int = 4,
                            miss_rate: float = 0.0, det_noise: float = 0.0,
                            seed: int = 0, img: float = 512.0,
                            size: float = 40.0):
    """Crowded crossing-paths scenario — maximal association ambiguity.

    Objects start evenly spaced on a circle and move on straight lines
    through the image center, so every pair crosses mid-sequence.  Classes
    alternate round-robin (both same-class and cross-class crossings
    occur — the class partition's regression scenario: a cross-class pair
    may momentarily have the highest IoU but must never match).
    ``miss_rate`` adds seeded detection dropout (occlusion-like gaps);
    detection order is shuffled per frame so slot order never encodes
    identity.

    Returns the same 7-tuple layout as :func:`generate_multiclass_scene`.
    """
    rng = np.random.default_rng(seed)
    f = num_frames
    eye = np.eye(embed_dim, dtype=np.float32)
    cls = (np.arange(num_objects) % num_classes).astype(np.int32)
    ang = 2.0 * np.pi * np.arange(num_objects) / num_objects
    r = img * 0.4
    c0 = img / 2.0 + r * np.stack([np.cos(ang), np.sin(ang)], -1)
    v = (img - 2.0 * c0) / max(f - 1, 1)       # reach the antipode at t=f-1
    gt_boxes = np.zeros((f, num_objects, 4), np.float32)
    gt_mask = np.ones((f, num_objects), bool)
    det_boxes = np.zeros((f, num_objects, 4), np.float32)
    det_mask = np.zeros((f, num_objects), bool)
    det_class = np.zeros((f, num_objects), np.int32)
    det_embed = np.zeros((f, num_objects, embed_dim), np.float32)
    for t in range(f):
        di = 0
        for i in rng.permutation(num_objects):
            c = c0[i] + v[i] * t
            box = np.array([c[0] - size / 2, c[1] - size / 2,
                            c[0] + size / 2, c[1] + size / 2], np.float32)
            gt_boxes[t, i] = box
            if rng.random() < miss_rate:
                continue
            det_boxes[t, di] = box + rng.normal(0, det_noise, 4)
            det_mask[t, di] = True
            det_class[t, di] = cls[i]
            det_embed[t, di] = eye[i % embed_dim]
            di += 1
    return gt_boxes, gt_mask, cls, det_boxes, det_mask, det_class, det_embed


def generate_batch(num_streams: int, cfg: SceneConfig):
    """Stack ``num_streams`` independent scenes -> dense stream batch.

    Returns ``det_boxes [F, S, D, 4]``, ``det_mask [F, S, D]``,
    plus per-stream ground truth lists for metric computation.
    """
    scenes = [generate_scene(dataclasses.replace(cfg, seed=cfg.seed + i))
              for i in range(num_streams)]
    d = max(s[2].shape[1] for s in scenes)
    f = cfg.num_frames
    det_boxes = np.zeros((f, num_streams, d, 4), np.float32)
    det_mask = np.zeros((f, num_streams, d), bool)
    for i, (_, _, db, dm) in enumerate(scenes):
        det_boxes[:, i, : db.shape[1]] = db
        det_mask[:, i, : dm.shape[1]] = dm
    gts = [(s[0], s[1]) for s in scenes]
    return det_boxes, det_mask, gts
