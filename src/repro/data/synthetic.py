"""Synthetic MOT workload generator.

Produces ground-truth multi-object trajectories plus noisy detections with
false positives and dropouts — statistically shaped like the MOT15 sequences
in paper Table I (≤13 simultaneous objects, hundreds of frames), so the
benchmarks can sweep stream counts far beyond the paper's 11 files.

Pure numpy on the host (this is the data pipeline, not the tracker).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    num_frames: int = 200
    max_objects: int = 12           # simultaneous objects cap (Table I max: 13)
    img_w: float = 1920.0
    img_h: float = 1080.0
    mean_size: float = 80.0         # mean box side, px
    speed: float = 8.0              # px/frame
    birth_rate: float = 0.05        # P(new object appears per frame)
    death_rate: float = 0.005       # P(object leaves per frame)
    det_noise: float = 2.0          # detection jitter, px
    miss_rate: float = 0.05         # P(detection dropout)
    fp_rate: float = 0.1            # expected false positives per frame
    seed: int = 0


def generate_scene(cfg: SceneConfig):
    """Simulate one video sequence.

    Returns
    -------
    gt_boxes : float32 ``[F, K, 4]`` xyxy ground truth (K = total objects ever)
    gt_mask  : bool    ``[F, K]`` object present in frame
    det_boxes: float32 ``[F, D, 4]`` noisy detections (padded)
    det_mask : bool    ``[F, D]``
    """
    rng = np.random.default_rng(cfg.seed)
    f = cfg.num_frames

    # --- simulate object lifecycles ---
    tracks = []  # (t_birth, t_death, trajectory [L, 4])
    active = []
    for _ in range(rng.integers(2, max(3, cfg.max_objects // 2 + 1))):
        active.append(_spawn(rng, cfg, 0))
    for t in range(1, f):
        if len(active) < cfg.max_objects and rng.random() < cfg.birth_rate:
            active.append(_spawn(rng, cfg, t))
        survivors = []
        for tr in active:
            if rng.random() < cfg.death_rate:
                tr["t_death"] = t
                tracks.append(tr)
            else:
                _step(tr, cfg)
                survivors.append(tr)
        active = survivors
    for tr in active:
        tr["t_death"] = f
        tracks.append(tr)

    k = len(tracks)
    gt_boxes = np.zeros((f, k, 4), np.float32)
    gt_mask = np.zeros((f, k), bool)
    for i, tr in enumerate(tracks):
        t0, t1 = tr["t_birth"], tr["t_death"]
        traj = np.asarray(tr["traj"][: t1 - t0], np.float32).reshape(-1, 4)
        gt_boxes[t0:t0 + len(traj), i] = traj
        gt_mask[t0:t0 + len(traj), i] = True

    # --- corrupt into detections ---
    d_max = cfg.max_objects + max(2, int(3 * cfg.fp_rate))
    det_boxes = np.zeros((f, d_max, 4), np.float32)
    det_mask = np.zeros((f, d_max), bool)
    for t in range(f):
        dets = []
        for i in range(k):
            if gt_mask[t, i] and rng.random() >= cfg.miss_rate:
                dets.append(gt_boxes[t, i] + rng.normal(0, cfg.det_noise, 4))
        n_fp = rng.poisson(cfg.fp_rate)
        for _ in range(n_fp):
            cx = rng.uniform(0, cfg.img_w)
            cy = rng.uniform(0, cfg.img_h)
            s = rng.uniform(0.5, 1.5) * cfg.mean_size
            dets.append([cx - s / 2, cy - s / 2, cx + s / 2, cy + s / 2])
        rng.shuffle(dets)
        dets = dets[:d_max]
        if dets:
            det_boxes[t, : len(dets)] = np.asarray(dets, np.float32)
            det_mask[t, : len(dets)] = True
    return gt_boxes, gt_mask, det_boxes, det_mask


def _spawn(rng, cfg, t):
    w = max(8.0, rng.normal(cfg.mean_size, cfg.mean_size / 4))
    h = max(8.0, rng.normal(cfg.mean_size * 2, cfg.mean_size / 3))  # pedestrian-ish
    cx = rng.uniform(w, cfg.img_w - w)
    cy = rng.uniform(h, cfg.img_h - h)
    vx, vy = rng.normal(0, cfg.speed, 2)
    box = [cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2]
    return {"t_birth": t, "t_death": None, "traj": [box],
            "v": (vx, vy), "wh": (w, h), "c": (cx, cy)}


def _step(tr, cfg):
    vx, vy = tr["v"]
    cx, cy = tr["c"]
    w, h = tr["wh"]
    cx = float(np.clip(cx + vx, w / 2, cfg.img_w - w / 2))
    cy = float(np.clip(cy + vy, h / 2, cfg.img_h - h / 2))
    tr["c"] = (cx, cy)
    tr["traj"].append([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2])


def generate_batch(num_streams: int, cfg: SceneConfig):
    """Stack ``num_streams`` independent scenes -> dense stream batch.

    Returns ``det_boxes [F, S, D, 4]``, ``det_mask [F, S, D]``,
    plus per-stream ground truth lists for metric computation.
    """
    scenes = [generate_scene(dataclasses.replace(cfg, seed=cfg.seed + i))
              for i in range(num_streams)]
    d = max(s[2].shape[1] for s in scenes)
    f = cfg.num_frames
    det_boxes = np.zeros((f, num_streams, d, 4), np.float32)
    det_mask = np.zeros((f, num_streams, d), bool)
    for i, (_, _, db, dm) in enumerate(scenes):
        det_boxes[:, i, : db.shape[1]] = db
        det_mask[:, i, : dm.shape[1]] = dm
    gts = [(s[0], s[1]) for s in scenes]
    return det_boxes, det_mask, gts
