"""MOT15 challenge text-format IO (paper Table I datasets).

Detection files are CSV lines::

    frame, id, bb_left, bb_top, bb_width, bb_height, conf, x, y, z

with ``id = -1`` for raw detections.  ``read_det_file`` parses into the
padded dense arrays the batched engine consumes; ``write_results`` emits the
MOT15 submission format the original SORT writes, so outputs are directly
comparable.
"""
from __future__ import annotations

import io
import os

import numpy as np

# Paper Table I: the 11 MOT15 train sequences and their sizes, used by the
# synthetic workload generator to mimic real stream statistics.
TABLE_I = {
    "PETS09-S2L1": (795, 8),
    "TUD-Campus": (71, 6),
    "TUD-Stadtmitte": (179, 7),
    "ETH-Bahnhof": (1000, 9),
    "ETH-Sunnyday": (354, 8),
    "ETH-Pedcross2": (837, 9),
    "KITTI-13": (340, 5),
    "KITTI-17": (145, 7),
    "ADL-Rundle-6": (525, 11),
    "ADL-Rundle-8": (654, 11),
    "Venice-2": (600, 13),
}


def read_det_file(path_or_buf, min_conf: float = 0.0,
                  max_dets: int | None = None, with_extras: bool = False):
    """Parse a MOT15 ``det.txt``.

    Returns ``det_boxes [F, D, 4] float32`` (xyxy), ``det_mask [F, D] bool``.
    With ``with_extras=True`` additionally returns ``det_class [F, D]
    int32`` (column 8 — the slot MOT16+ ground truth uses for the object
    class; ``-1`` where the file carries none) and ``det_conf [F, D]
    float32`` (column 7), feeding the multi-class engine configs
    (DESIGN.md §10) without a second parse.
    """
    if isinstance(path_or_buf, (str, os.PathLike)):
        with open(path_or_buf) as fh:
            raw = fh.read()
    else:
        raw = path_or_buf.read()

    def empty():
        # empty / whitespace-only det file (a sequence with no detections,
        # or write_det_file of a zero-frame batch): np.loadtxt would choke
        # parsing it, so short-circuit to the well-formed zero-frame batch.
        db = np.zeros((0, 1, 4), np.float32)
        dm = np.zeros((0, 1), bool)
        if not with_extras:
            return db, dm
        return db, dm, np.full((0, 1), -1, np.int32), np.zeros((0, 1),
                                                               np.float32)

    if not raw.strip():
        return empty()
    rows = np.loadtxt(io.StringIO(raw), delimiter=",", ndmin=2)
    if rows.size == 0:
        return empty()
    frames = rows[:, 0].astype(int)
    conf_ok = rows[:, 6] >= min_conf
    rows, frames = rows[conf_ok], frames[conf_ok]
    if frames.size == 0:  # every row filtered out by min_conf
        return empty()
    f_max = int(frames.max())
    counts = np.bincount(frames - 1, minlength=f_max)
    d = int(counts.max()) if max_dets is None else max_dets
    det_boxes = np.zeros((f_max, d, 4), np.float32)
    det_mask = np.zeros((f_max, d), bool)
    det_class = np.full((f_max, d), -1, np.int32)
    det_conf = np.zeros((f_max, d), np.float32)
    cursor = np.zeros(f_max, int)
    for r in rows:
        t = int(r[0]) - 1
        i = cursor[t]
        if i >= d:
            continue
        x, y, w, h = r[2], r[3], r[4], r[5]
        det_boxes[t, i] = [x, y, x + w, y + h]
        det_mask[t, i] = True
        det_conf[t, i] = np.float32(r[6])
        if len(r) > 7:
            det_class[t, i] = int(round(float(r[7])))
        cursor[t] += 1
    if not with_extras:
        return det_boxes, det_mask
    return det_boxes, det_mask, det_class, det_conf


def write_results(path, boxes, uids, emit):
    """Write tracking output in MOT15 submission format.

    ``boxes [F, T, 4]`` xyxy, ``uids [F, T]``, ``emit [F, T]`` bool.
    """
    with open(path, "w") as fh:
        for t in range(boxes.shape[0]):
            for k in np.where(emit[t])[0]:
                x1, y1, x2, y2 = boxes[t, k]
                fh.write(f"{t + 1},{int(uids[t, k])},{x1:.2f},{y1:.2f},"
                         f"{x2 - x1:.2f},{y2 - y1:.2f},1,-1,-1,-1\n")


def write_det_file(path, det_boxes, det_mask, det_class=None, det_conf=None):
    """Inverse of :func:`read_det_file` (used to round-trip synthetic data).

    ``det_class [F, D]`` int fills column 8 and ``det_conf [F, D]`` column 7
    (``%.9g`` — enough significant digits that a float32 confidence
    round-trips exactly); omitted they keep the historical ``-1`` / ``1``
    placeholders, emitting byte-identical files to before.
    """
    with open(path, "w") as fh:
        for t in range(det_boxes.shape[0]):
            for k in np.where(det_mask[t])[0]:
                x1, y1, x2, y2 = det_boxes[t, k]
                conf = ("1" if det_conf is None
                        else f"{np.float32(det_conf[t, k]):.9g}")
                c = -1 if det_class is None else int(det_class[t, k])
                fh.write(f"{t + 1},-1,{x1:.2f},{y1:.2f},"
                         f"{x2 - x1:.2f},{y2 - y1:.2f},{conf},{c},-1,-1\n")
