from . import mot, stream, synthetic  # noqa: F401
