"""Synthetic LM token pipeline (training substrate for the arch zoo).

Generates Zipf-distributed token streams with locally coherent n-gram
structure (so the loss actually decreases during the example training runs),
packs them into fixed-length sequences, and shards the host batch onto the
mesh.  Modality variants produce the audio-frame / vision-patch stand-ins
the ``[audio]``/``[vlm]`` archs consume.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Deterministic synthetic corpus with a repeating-bigram backbone."""

    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        # fixed random bigram table gives the model something learnable
        self._next = self.rng.integers(0, vocab_size,
                                       size=(vocab_size,), dtype=np.int32)

    def sample(self, batch: int, seq_len: int):
        start = (self.rng.zipf(self.zipf_a, size=(batch,)) - 1) % self.vocab
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = start
        noise = self.rng.random((batch, seq_len)) < 0.1
        rand = self.rng.integers(0, self.vocab, size=(batch, seq_len))
        for t in range(seq_len):
            nxt = self._next[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return toks

    def batch(self, batch: int, seq_len: int) -> dict:
        toks = self.sample(batch, seq_len)
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}


def audio_batch(rng, batch, seq_len, d_model, vocab, mask_rate=0.08,
                span=10):
    """HuBERT-style masked-prediction batch: frame feats + span masks."""
    feats = rng.normal(0, 1, size=(batch, seq_len, d_model)).astype(np.float32)
    labels = rng.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
    starts = rng.random((batch, seq_len)) < mask_rate / span
    mask = np.zeros((batch, seq_len), bool)
    for s in range(span):
        mask[:, s:] |= starts[:, :seq_len - s]
    return {"feats": feats, "labels": labels, "mask_spans": mask,
            "loss_mask": mask.astype(np.float32)}


def vision_batch(rng, batch, text_len, num_patches, frontend_dim, vocab,
                 stream: TokenStream):
    """LLaVA-style batch: CLIP patch features + text tokens."""
    b = stream.batch(batch, text_len)
    patches = rng.normal(0, 1, size=(batch, num_patches,
                                     frontend_dim)).astype(np.float32)
    return {"tokens": b["tokens"], "labels": b["labels"], "patches": patches}
