"""Stream batcher — packs independent video sequences into dense batches.

The paper's throughput scaling assigns one video file per worker.  Here the
unit of parallelism is a *lane* in a dense ``[F, S, D, 4]`` batch, and the
stream axis ``S`` is sharded over the ``(pod, data)`` mesh axes
(``repro.sharding``).  Sequences of different lengths are length-bucketed so
short streams don't stall long ones — the straggler-mitigation analogue of
the paper replicating its 11 files to keep 72 cores busy.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamBatch:
    det_boxes: np.ndarray   # [F, S, D, 4]
    det_mask: np.ndarray    # [F, S, D]
    frame_valid: np.ndarray  # [F, S] — stream still live at this frame
    names: tuple


def pack(sequences, max_dets: int | None = None, pad_multiple: int = 1):
    """Pack ``[(name, det_boxes [F_i, D_i, 4], det_mask [F_i, D_i])]`` into a
    dense batch padded to the longest sequence (and ``S`` to ``pad_multiple``,
    so the stream axis divides the mesh's data parallelism).

    Degenerate inputs stay well-formed: an empty sequence list yields a
    ``[0, 0, D, 4]`` batch, zero/single-frame sequences pack like any other
    length, and ``pad_multiple`` never shrinks an already-aligned ``S``.
    """
    if pad_multiple < 1:
        raise ValueError(f"pad_multiple must be >= 1, got {pad_multiple}")
    names = tuple(s[0] for s in sequences)
    f = max((s[1].shape[0] for s in sequences), default=0)
    d = max_dets or max((s[1].shape[1] for s in sequences), default=1)
    s_real = len(sequences)
    s_pad = -(-s_real // pad_multiple) * pad_multiple
    det_boxes = np.zeros((f, s_pad, d, 4), np.float32)
    det_mask = np.zeros((f, s_pad, d), bool)
    frame_valid = np.zeros((f, s_pad), bool)
    for i, (_, db, dm) in enumerate(sequences):
        fi, di = db.shape[0], min(db.shape[1], d)
        det_boxes[:fi, i, :di] = db[:, :di]
        det_mask[:fi, i, :di] = dm[:, :di]
        frame_valid[:fi, i] = True
    return StreamBatch(det_boxes, det_mask, frame_valid, names)


def length_buckets(sequences, num_buckets: int = 4):
    """Group sequences into length buckets (straggler mitigation: a 71-frame
    TUD-Campus never pads out to a 1000-frame ETH-Bahnhof).

    Never returns empty buckets: with fewer sequences than buckets each
    bucket holds one sequence, and an empty input yields no buckets at all.
    """
    if num_buckets < 1:
        raise ValueError(f"num_buckets must be >= 1, got {num_buckets}")
    seqs = sorted(sequences, key=lambda s: s[1].shape[0])
    n = len(seqs)
    if n == 0:
        return []
    out = []
    per = -(-n // num_buckets)
    for i in range(0, n, per):
        out.append(seqs[i:i + per])
    return out


def replicate(sequences, times: int):
    """Paper §VI: 'We replicated the input files 7 times' — same knob."""
    out = []
    for r in range(times):
        for name, db, dm in sequences:
            out.append((f"{name}#{r}", db, dm))
    return out


# ---------------------------------------------------------------- draining
@dataclasses.dataclass(frozen=True)
class SequenceTracks:
    """One finished sequence's track stream, dense over its own frames.

    ``boxes [F_i, T, 4]`` xyxy, ``uid [F_i, T]`` int32, ``emit [F_i, T]``
    bool — the rows of :class:`repro.core.SortOutput` that belonged to this
    sequence, in frame order, exactly as a solo run would have produced
    them (the ragged scheduler's lane-recycling invariant, DESIGN.md §3).
    ``cls [F_i, T]`` int32 carries each slot's track class (DESIGN.md §10);
    ``None`` for single-class serving.
    """

    name: str
    boxes: np.ndarray
    uid: np.ndarray
    emit: np.ndarray
    cls: np.ndarray | None = None

    @property
    def num_frames(self) -> int:
        return self.boxes.shape[0]


class ReorderBuffer:
    """In-order release of out-of-order completions.

    Sequences multiplexed over recycled lanes finish in length order, not
    submission order; ``put(index, item)`` parks a completion and
    ``pop_ready()`` releases the longest run of consecutively-indexed items
    starting at the watermark — so consumers (result writers, metric
    aggregators) always observe submission order, the scheduler's
    drain/flush contract.
    """

    def __init__(self, start: int = 0):
        self._next = start
        self._held: dict[int, object] = {}

    @property
    def next_index(self) -> int:
        """The watermark: the submission index the next release starts at.
        Everything below it has already been released (the serving
        checkpoint records this, DESIGN.md §11)."""
        return self._next

    @property
    def held_indices(self) -> tuple[int, ...]:
        """Indices parked above the watermark, ascending."""
        return tuple(sorted(self._held))

    def put(self, index: int, item) -> None:
        if index < self._next or index in self._held:
            raise ValueError(f"sequence index {index} already released")
        self._held[index] = item

    def peek(self, index: int):
        """The parked item at ``index`` without releasing it (serving
        checkpoint export reads held completions through this)."""
        return self._held[index]

    def pop_ready(self) -> list:
        out = []
        while self._next in self._held:
            out.append(self._held.pop(self._next))
            self._next += 1
        return out

    def __len__(self) -> int:
        return len(self._held)
