"""Pluggable association costs (DESIGN.md §10).

The association step scores every (detection, tracker) pair and solves an
assignment on the resulting *extremely small* matrix.  The score has
always been plain IoU; this module makes it a composable spec:

``score = iou_weight * IoU  +  embed_weight * <det_embed, trk_embed>``

plus two *hard feasibility* terms that mask pairs out of the solve
entirely (cost-matrix masking, not score shaping):

* **class partition** — when the engine runs ``num_classes > 1``, a
  detection can only match a tracker of the same class.  Masking the
  cross-class pairs makes the cost matrix block-diagonal by class, so
  Hungarian and greedy both solve every per-class sub-problem in a
  single lane-batched call — no per-class loop, no extra dispatches
  (the CORT observation from PAPERS.md, in our sweet spot: the blocks
  are even smaller than the already-tiny full matrix).
* **Mahalanobis gate** — the classic motion gate: a pair is feasible
  only if the squared Mahalanobis distance of the detection's observation
  from the tracker's *predicted* observation distribution
  (``S = H P' Hᵀ + R``, the innovation covariance) is under a chi²
  quantile.

Both evaluators exist in **both layouts** — batch-major ``[..., D, T]``
for the per-phase engine path and lane-major ``[D, T, lanes]`` for the
fused kernels — sharing the same trace-time-unrolled term order, exactly
as ``associate`` / ``associate_lane`` share ``_gate_and_invert``.  The
IoU threshold stays a *post-solve* gate (``association._gate_and_invert``
semantics); feasibility additionally enters that gate so an infeasible
pair can never survive the solve.

The default spec (pure IoU, one class) produces ``score=None,
feasible=None`` everywhere, which keeps every downstream consumer on the
byte-identical pre-existing code path — single-class IoU runs are
bit-identical to an engine without this module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["CHI2_GATE_4DOF", "CostSpec", "IOU", "iou_maha", "iou_embed",
           "parse_cost", "needs_score", "needs_feasible",
           "score_and_feasible_batch", "score_and_feasible_lane"]

# 0.95 quantile of the chi-squared distribution with 4 degrees of freedom
# (one per observed dimension of z = [x, y, s, r]) — the standard
# Mahalanobis gate threshold (DeepSORT uses the same quantile family).
CHI2_GATE_4DOF = 9.487729036781154


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """A composable association cost: IoU ⊕ Mahalanobis gate ⊕ embedding.

    Frozen and hashable, so it rides inside ``SortConfig`` and through
    jit static arguments unchanged.

    * ``iou_weight`` — weight of the IoU term in the score.
    * ``maha_gate`` — chi² threshold on the squared Mahalanobis distance
      (``None`` = no motion gate).  A *hard* feasibility mask.
    * ``embed_weight`` / ``embed_dim`` — appearance term: the dot product
      of L2-normalizable per-detection / per-track embedding vectors of
      length ``embed_dim``, scaled by ``embed_weight``.
    """

    iou_weight: float = 1.0
    maha_gate: Optional[float] = None
    embed_weight: float = 0.0
    embed_dim: int = 0

    def __post_init__(self):
        if self.embed_weight != 0.0 and self.embed_dim <= 0:
            raise ValueError(
                f"embed_weight={self.embed_weight} needs embed_dim > 0")
        if self.embed_dim < 0:
            raise ValueError(f"embed_dim must be >= 0, got {self.embed_dim}")
        if self.maha_gate is not None and self.maha_gate <= 0.0:
            raise ValueError(f"maha_gate must be > 0, got {self.maha_gate}")

    @property
    def uses_maha(self) -> bool:
        return self.maha_gate is not None

    @property
    def uses_embed(self) -> bool:
        return self.embed_weight != 0.0 and self.embed_dim > 0

    @property
    def is_iou_only(self) -> bool:
        """True when the score is plain IoU with no extra feasibility —
        the config that must stay bit-identical to the pre-cost engine."""
        return (self.iou_weight == 1.0 and not self.uses_maha
                and not self.uses_embed)


IOU = CostSpec()


def iou_maha(gate: float = CHI2_GATE_4DOF) -> CostSpec:
    """IoU score + hard Mahalanobis motion gate."""
    return CostSpec(maha_gate=gate)


def iou_embed(embed_dim: int, weight: float = 0.5) -> CostSpec:
    """IoU score blended with an appearance-embedding dot product."""
    return CostSpec(embed_weight=weight, embed_dim=embed_dim)


def parse_cost(name: str, embed_dim: int = 4) -> CostSpec:
    """CLI spelling -> :class:`CostSpec` (``examples/tracking_service.py
    --cost``)."""
    if name == "iou":
        return IOU
    if name == "iou+maha":
        return iou_maha()
    if name == "iou+embed":
        return iou_embed(embed_dim)
    raise ValueError(f"unknown cost {name!r}; pick from "
                     f"'iou', 'iou+maha', 'iou+embed'")


def needs_score(cost: CostSpec) -> bool:
    """True when the solve must run on a combined score instead of raw
    IoU.  False keeps the solver inputs byte-identical to the pre-cost
    path (the bit-identity contract)."""
    return cost.iou_weight != 1.0 or cost.uses_embed


def needs_feasible(cost: CostSpec, num_classes: int) -> bool:
    """True when a hard pair-feasibility mask must enter the solve."""
    return num_classes > 1 or cost.uses_maha


# --------------------------------------------------------------- Mahalanobis
def _innovation_inv(p4):
    """Inverse innovation covariance ``(P'₄ₓ₄ + R)⁻¹`` from the predicted
    covariance's top-left 4×4 block, given as nested ``[[a₀₀..]..]`` lists
    of same-shape arrays.  Uses the kernels' exact branch-free blockwise
    SPD inverse so both layouts (and the in-kernel evaluation) share one
    expression tree — identical floats, identical gate decisions."""
    from repro.kernels import ref as kref

    s = [[p4[i][j] + (kref.R_DIAG[i] if i == j else 0.0)
          for j in range(4)] for i in range(4)]
    return kref._inv4(s)


def _maha_terms(y, sinv):
    """``Σᵢⱼ yᵢ · S⁻¹ᵢⱼ · yⱼ`` with a fixed i-major / j-minor term order
    (shared by both layout wrappers, so they accumulate identically)."""
    d2 = None
    for i in range(4):
        for j in range(4):
            term = y[i] * sinv[i][j] * y[j]
            d2 = term if d2 is None else d2 + term
    return d2


# ----------------------------------------------------------- lane evaluator
def score_and_feasible_lane(iou, cost: CostSpec, *, num_classes: int = 1,
                            det_class=None, trk_cls=None,
                            det_embed=None, trk_embed=None,
                            z_det=None, x_pred=None, p4_pred=None):
    """Lane-major score/feasibility for the fused kernels.

    ``iou [D, T, ...]``; ``det_class [D, ...]`` / ``trk_cls [T, ...]``
    int32; ``det_embed [D, E, ...]`` / ``trk_embed [E, T, ...]``;
    ``z_det [4, D, ...]`` observations; ``x_pred [>=4, T, ...]``
    *post-predict* means; ``p4_pred`` the post-predict covariance's 4×4
    block as nested lists of ``[T, ...]`` arrays.

    Returns ``(score, feasible)`` with ``None`` for any term the spec
    does not use — so the pure-IoU single-class config hands the solvers
    exactly the arguments they got before this module existed.  Every
    loop is trace-time unrolled (kernel-safe, DESIGN.md §2.3) and the
    term order matches :func:`score_and_feasible_batch` exactly.
    """
    score = None
    if needs_score(cost):
        score = cost.iou_weight * iou
        if cost.uses_embed:
            dot = None
            for e in range(cost.embed_dim):
                term = det_embed[:, e][:, None] * trk_embed[e][None]
                dot = term if dot is None else dot + term
            score = score + cost.embed_weight * dot
    feasible = None
    if num_classes > 1:
        feasible = det_class[:, None] == trk_cls[None]
    if cost.uses_maha:
        sinv = _innovation_inv(p4_pred)
        y = [z_det[i][:, None] - x_pred[i][None] for i in range(4)]
        d2 = _maha_terms(y, [[sinv[i][j][None] for j in range(4)]
                             for i in range(4)])
        ok = d2 <= cost.maha_gate
        feasible = ok if feasible is None else feasible & ok
    return score, feasible


# ---------------------------------------------------------- batch evaluator
def score_and_feasible_batch(iou, cost: CostSpec, *, num_classes: int = 1,
                             det_class=None, trk_cls=None,
                             det_embed=None, trk_embed=None,
                             z_det=None, x_pred=None, p4_pred=None):
    """Batch-major twin of :func:`score_and_feasible_lane` for the
    per-phase engine path.

    ``iou [..., D, T]``; ``det_class [..., D]`` / ``trk_cls [..., T]``;
    ``det_embed [..., D, E]`` / ``trk_embed [..., T, E]``;
    ``z_det [..., D, 4]``; ``x_pred [..., T, >=4]`` post-predict means;
    ``p4_pred [..., T, 4, 4]`` the post-predict covariance block.

    Same unrolled term order as the lane evaluator, so per-pair scores
    and gate decisions are bit-identical across layouts.
    """
    score = None
    if needs_score(cost):
        score = cost.iou_weight * iou
        if cost.uses_embed:
            dot = None
            for e in range(cost.embed_dim):
                term = (det_embed[..., :, e][..., :, None]
                        * trk_embed[..., :, e][..., None, :])
                dot = term if dot is None else dot + term
            score = score + cost.embed_weight * dot
    feasible = None
    if num_classes > 1:
        feasible = det_class[..., :, None] == trk_cls[..., None, :]
    if cost.uses_maha:
        p4 = [[p4_pred[..., i, j] for j in range(4)] for i in range(4)]
        sinv = _innovation_inv(p4)
        y = [z_det[..., :, i][..., :, None] - x_pred[..., :, i][..., None, :]
             for i in range(4)]
        d2 = _maha_terms(y, [[sinv[i][j][..., None, :] for j in range(4)]
                             for i in range(4)])
        ok = d2 <= cost.maha_gate
        feasible = ok if feasible is None else feasible & ok
    return score, feasible
