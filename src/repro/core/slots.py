"""Fixed-capacity slot pools — static-shape lifecycle management.

JAX requires static shapes, but both of this framework's dynamic populations
— SORT trackers (born on unmatched detections, killed after ``max_age``
misses) and decode-server sequences (admitted on request, evicted on EOS) —
grow and shrink per step.  The paper manages trackers with Python list
append/delete; the TPU-native equivalent is a fixed pool of ``T`` slots per
stream with an ``alive`` mask and branch-free claim/kill operations.

This module is deliberately generic: ``repro.core.sort`` uses it for
trackers and ``repro.serving`` uses it for continuous batching.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

# uid headroom guard: ``next_uid`` is a per-stream int32 counter that only
# resets when the stream is re-initialised (``core.sort.reset_ragged`` —
# every scheduler admission starts a fresh uid namespace).  Births allocate
# at most D uids per frame, so a counter below this limit cannot reach
# int32 overflow within any chunk the scheduler dispatches (2**20 of slack
# covers ~65k frames at D=16 between host checks).  Callers that keep one
# stream alive long enough to cross it must fail loudly instead of
# wrapping onto ids that may still be alive (serve/scheduler.py raises).
UID_LIMIT = 2**31 - 2**20


class SlotPool(NamedTuple):
    """Per-slot lifecycle bookkeeping. All fields ``[..., T]`` (+ scalar uid ctr).

    ``alive``: slot holds a live entity.
    ``age``: steps since birth.
    ``hits``: total successful updates (matches).
    ``hit_streak``: consecutive successful updates.
    ``time_since_update``: steps since last successful update.
    ``uid``: globally unique id (per stream), -1 when dead.
    ``cls``: object class of the slot's entity (DESIGN.md §10), -1 when
    dead.  Set once at birth from the claiming detection's class (0 for
    single-class runs) and constant for the track's lifetime — the class
    partition makes cross-class matches infeasible, so a track can never
    be updated by a detection of another class.
    ``next_uid``: ``[...]`` per-stream counter for id assignment.  Grows
    monotonically for the stream's lifetime and resets to ``uid_start``
    only on re-init (``core.sort.reset_ragged``), so recycled lanes start
    a fresh uid namespace with no live uid carried over; :data:`UID_LIMIT`
    bounds how far a single stream may push it before the serving layer
    refuses to continue (int32 overflow would alias live ids).
    """

    alive: jnp.ndarray
    age: jnp.ndarray
    hits: jnp.ndarray
    hit_streak: jnp.ndarray
    time_since_update: jnp.ndarray
    uid: jnp.ndarray
    cls: jnp.ndarray
    next_uid: jnp.ndarray

    @property
    def capacity(self) -> int:
        return self.alive.shape[-1]

    @property
    def num_alive(self) -> jnp.ndarray:
        return self.alive.sum(axis=-1)


def init_pool(batch_shape: tuple, capacity: int, uid_start: int = 1) -> SlotPool:
    shape = batch_shape + (capacity,)
    z = jnp.zeros(shape, jnp.int32)
    return SlotPool(
        alive=jnp.zeros(shape, bool),
        age=z, hits=z, hit_streak=z, time_since_update=z,
        uid=jnp.full(shape, -1, jnp.int32),
        cls=jnp.full(shape, -1, jnp.int32),
        next_uid=jnp.full(batch_shape, uid_start, jnp.int32),
    )


def assign_slots(free_mask: jnp.ndarray, want_mask: jnp.ndarray) -> jnp.ndarray:
    """Rank-match claimants to free slots, branch-free.

    ``free_mask [..., T]``: slots available.  ``want_mask [..., D]``:
    claimants.  Returns ``slot_for [..., D] int32``: the claimed slot per
    claimant, or -1 if the pool is exhausted (claim dropped — the same
    back-pressure a real tracker/server applies).

    The k-th claimant (in index order) takes the k-th free slot: a
    rank-matching computed with cumsums and one scatter; O(T + D) work per
    stream, no sorting, no data-dependent shapes.
    """
    t = free_mask.shape[-1]
    d = want_mask.shape[-1]
    batch = jnp.broadcast_shapes(free_mask.shape[:-1], want_mask.shape[:-1])
    free_mask = jnp.broadcast_to(free_mask, batch + (t,))
    want_mask = jnp.broadcast_to(want_mask, batch + (d,))

    free_rank = jnp.cumsum(free_mask, axis=-1) - 1          # rank of each free slot
    want_rank = jnp.cumsum(want_mask, axis=-1) - 1          # rank of each claimant
    num_free = free_mask.sum(axis=-1, keepdims=True)

    # slot_of_rank[r] = index of the r-th free slot (overflow row t -> dropped)
    slot_of_rank = jnp.full(batch + (t + 1,), -1, jnp.int32)
    scatter_to = jnp.where(free_mask, free_rank, t)
    slot_idx = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), batch + (t,))
    flat = slot_of_rank.reshape((-1, t + 1))
    rows = jnp.arange(flat.shape[0])[:, None]
    flat = flat.at[rows, scatter_to.reshape((-1, t))].set(slot_idx.reshape((-1, t)))
    slot_of_rank = flat.reshape(batch + (t + 1,))

    ok = want_mask & (want_rank < num_free)
    lookup = jnp.where(ok, want_rank, t).astype(jnp.int32)
    slot_for = jnp.take_along_axis(slot_of_rank, lookup, axis=-1)
    return jnp.where(ok, slot_for, -1).astype(jnp.int32)


def birth(pool: SlotPool, slot_for: jnp.ndarray,
          det_class=None) -> SlotPool:
    """Activate claimed slots (``slot_for`` from :func:`assign_slots`).

    ``det_class [..., D] int32`` (optional) stamps each born slot with its
    claiming detection's class; ``None`` births class 0 (single-class)."""
    t = pool.capacity
    batch = pool.alive.shape[:-1]
    claimed = slot_for >= 0                                  # [..., D]
    target = jnp.where(claimed, slot_for, t)                 # overflow -> t

    def scat(field, value):
        ext = jnp.concatenate([field, field[..., :1]], axis=-1)  # overflow col
        flat = ext.reshape((-1, t + 1))
        rows = jnp.arange(flat.shape[0])[:, None]
        v = jnp.broadcast_to(value, target.shape).reshape((-1, target.shape[-1]))
        flat = flat.at[rows, target.reshape((-1, target.shape[-1]))].set(v)
        return flat.reshape(batch + (t + 1,))[..., :t]

    # uid: k-th claimant gets next_uid + k
    order = jnp.cumsum(claimed, axis=-1) - 1
    uids = pool.next_uid[..., None] + jnp.where(claimed, order, 0)
    n_born = claimed.sum(axis=-1)
    cls_val = (jnp.zeros(target.shape, jnp.int32) if det_class is None
               else det_class.astype(jnp.int32))
    return SlotPool(
        alive=scat(pool.alive, True),
        age=scat(pool.age, 0),
        hits=scat(pool.hits, 0),
        hit_streak=scat(pool.hit_streak, 0),
        time_since_update=scat(pool.time_since_update, 0),
        uid=scat(pool.uid, uids.astype(jnp.int32)),
        cls=scat(pool.cls, cls_val),
        next_uid=pool.next_uid + n_born.astype(jnp.int32),
    )


def resize_pool(pool: SlotPool, num_streams: int,
                uid_start: int = 1) -> SlotPool:
    """Resize an engine-layout pool (slot fields ``[S, T]``, ``next_uid
    [S]``) on the stream axis — the lane-migration primitive behind
    elastic lane budgets (DESIGN.md §8).

    Shrink slices the leading streams (the caller must have drained the
    dropped tail — live trackers there would vanish silently); grow
    appends streams carrying the :func:`init_pool` values (``alive=False``,
    ``uid=-1``, ``next_uid=uid_start``), so a grown pool is bit-identical
    to one whose new streams were just re-initialised.  Kept streams are
    untouched bit for bit in both directions, which is what lets a
    mid-sequence lane survive a budget migration exactly
    (``tests/test_autoscale.py``).
    """
    s = pool.next_uid.shape[0]
    if num_streams < 1:
        raise ValueError(f"num_streams must be >= 1, got {num_streams}")
    if num_streams == s:
        return pool
    if num_streams < s:
        return pool._replace(
            **{f: getattr(pool, f)[:num_streams]
               for f in ("alive", "age", "hits", "hit_streak",
                         "time_since_update", "uid", "cls")},
            next_uid=pool.next_uid[:num_streams])
    grow = ((0, num_streams - s), (0, 0))
    zero_grow = {f: jnp.pad(getattr(pool, f), grow)
                 for f in ("age", "hits", "hit_streak", "time_since_update")}
    return pool._replace(
        alive=jnp.pad(pool.alive, grow),
        uid=jnp.pad(pool.uid, grow, constant_values=-1),
        cls=jnp.pad(pool.cls, grow, constant_values=-1),
        next_uid=jnp.pad(pool.next_uid, ((0, num_streams - s),),
                         constant_values=uid_start),
        **zero_grow)


def transpose_pool(pool: SlotPool) -> SlotPool:
    """Swap the slot axis between last (engine layout ``[..., T]``) and
    first (lane layout ``[T, ...]``, slots on sublanes, streams on lanes).

    Involution: ``transpose_pool(transpose_pool(p)) == p``.  Lane-layout
    pools are what ``core.sort.LaneSortState`` keeps resident; the
    per-slot fields are small ints, so the occasional transpose to reuse
    :func:`assign_slots`/:func:`birth` is off the covariance hot path.
    ``next_uid`` carries no slot axis and passes through.
    """
    return pool._replace(
        **{f: jnp.moveaxis(getattr(pool, f), -1, 0)
           for f in ("alive", "age", "hits", "hit_streak",
                     "time_since_update", "uid", "cls")})


def assign_slots_lane(free_mask: jnp.ndarray, want_mask: jnp.ndarray) -> jnp.ndarray:
    """:func:`assign_slots` for lane layout: ``free [T, ...]``,
    ``want [D, ...]`` -> ``slot_for [D, ...]``."""
    out = assign_slots(jnp.moveaxis(free_mask, 0, -1),
                       jnp.moveaxis(want_mask, 0, -1))
    return jnp.moveaxis(out, -1, 0)


def birth_lane(pool: SlotPool, slot_for: jnp.ndarray,
               det_class=None) -> SlotPool:
    """:func:`birth` for a lane-layout pool (fields ``[T, ...]``,
    ``slot_for [D, ...]``, ``det_class [D, ...]``)."""
    born = birth(transpose_pool(pool), jnp.moveaxis(slot_for, 0, -1),
                 det_class=(None if det_class is None
                            else jnp.moveaxis(det_class, 0, -1)))
    return transpose_pool(born)


def tick(pool: SlotPool, matched: jnp.ndarray, max_age: int) -> SlotPool:
    """Advance one step: matched slots refresh, unmatched age out.

    ``matched [..., T]``: alive slots updated this step.  Slots whose
    ``time_since_update`` exceeds ``max_age`` die.

    Purely elementwise, so it works unchanged on lane-layout pools
    (fields ``[T, ...]`` with ``matched [T, ...]``).
    """
    alive = pool.alive
    hit = alive & matched
    miss = alive & ~matched
    tsu = jnp.where(hit, 0, pool.time_since_update + miss.astype(jnp.int32))
    new_alive = alive & (tsu <= max_age)
    return pool._replace(
        alive=new_alive,
        age=jnp.where(alive, pool.age + 1, pool.age),
        hits=pool.hits + hit.astype(jnp.int32),
        hit_streak=jnp.where(hit, pool.hit_streak + 1,
                             jnp.where(miss, 0, pool.hit_streak)),
        time_since_update=tsu,
        uid=jnp.where(new_alive, pool.uid, -1),
        cls=jnp.where(new_alive, pool.cls, -1),
    )
