"""Batched constant-velocity Kalman filter — the SORT motion model.

State (paper Table II): ``x = [u, v, s, r, du, dv, ds]`` (dim_x = 7),
observation ``z = [u, v, s, r]`` (dim_z = 4).  ``F`` is the constant-velocity
transition, ``H`` selects the first four state components.

The paper's central observation is that these matrices are *extremely small*
(7x7, 4x7, 4x4): no single filter can use a wide machine.  We therefore keep
the filter *structure-of-arrays batched*: every function takes states with an
arbitrary leading batch shape ``[...,]`` and performs the tiny-matrix algebra
as trace-time-unrolled einsums so the batch axis lands on the vector lanes.

The innovation covariance ``S`` is 4x4; we invert it with a branch-free
closed-form blockwise inverse (exact for SPD matrices) instead of Cholesky —
see DESIGN.md §5 "What did NOT transfer".
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

DIM_X = 7
DIM_Z = 4

# --- SORT's filter constants (Bewley et al. reference implementation). ---


def transition_matrix(dtype=jnp.float32) -> jnp.ndarray:
    f = np.eye(DIM_X)
    f[0, 4] = 1.0  # u  += du
    f[1, 5] = 1.0  # v  += dv
    f[2, 6] = 1.0  # s  += ds
    return jnp.asarray(f, dtype)


def observation_matrix(dtype=jnp.float32) -> jnp.ndarray:
    h = np.zeros((DIM_Z, DIM_X))
    h[np.arange(4), np.arange(4)] = 1.0
    return jnp.asarray(h, dtype)


def measurement_noise(dtype=jnp.float32) -> jnp.ndarray:
    r = np.eye(DIM_Z)
    r[2, 2] = 10.0
    r[3, 3] = 10.0
    return jnp.asarray(r, dtype)


def process_noise(dtype=jnp.float32) -> jnp.ndarray:
    q = np.eye(DIM_X)
    q[4, 4] = 0.01
    q[5, 5] = 0.01
    q[6, 6] = 1e-4
    return jnp.asarray(q, dtype)


def initial_covariance_np() -> np.ndarray:
    """Host-side :func:`initial_covariance` — the chunk megakernel body
    needs the entries as Python scalars (Pallas kernels may not capture
    non-scalar constants), so the values live here, numpy-first."""
    p = np.eye(DIM_X) * 10.0
    p[4, 4] = p[5, 5] = p[6, 6] = 1e4  # high uncertainty on unobserved velocities
    return p


def initial_covariance(dtype=jnp.float32) -> jnp.ndarray:
    return jnp.asarray(initial_covariance_np(), dtype)


class KalmanParams(NamedTuple):
    """Static filter matrices, shared by every tracker in every stream."""

    F: jnp.ndarray  # [7, 7]
    H: jnp.ndarray  # [4, 7]
    Q: jnp.ndarray  # [7, 7]
    R: jnp.ndarray  # [4, 4]

    @staticmethod
    def default(dtype=jnp.float32) -> "KalmanParams":
        return KalmanParams(
            F=transition_matrix(dtype),
            H=observation_matrix(dtype),
            Q=process_noise(dtype),
            R=measurement_noise(dtype),
        )


def init_state(z: jnp.ndarray, dtype=jnp.float32):
    """Seed a tracker from an observation ``z [..., 4]``.

    Returns ``(x [..., 7], P [..., 7, 7])`` with zero velocity and the SORT
    initial covariance.
    """
    batch = z.shape[:-1]
    x = jnp.concatenate([z, jnp.zeros(batch + (3,), dtype)], axis=-1)
    p = jnp.broadcast_to(initial_covariance(dtype), batch + (DIM_X, DIM_X))
    return x.astype(dtype), p


def predict(x: jnp.ndarray, p: jnp.ndarray, params: KalmanParams):
    """Time update: ``x <- F x``, ``P <- F P F^T + Q``.

    SORT detail: if the predicted scale would go non-positive, the scale
    velocity is zeroed first (a tracked box cannot invert).
    """
    ds = jnp.where(x[..., 2] + x[..., 6] <= 0.0, 0.0, x[..., 6])
    x = x.at[..., 6].set(ds)
    x_new = jnp.einsum("ij,...j->...i", params.F, x)
    p_new = jnp.einsum("ij,...jk,lk->...il", params.F, p, params.F) + params.Q
    return x_new, p_new


def inv4_spd(s: jnp.ndarray) -> jnp.ndarray:
    """Branch-free blockwise inverse of a batch of SPD 4x4 matrices.

    ``S = [[A, B], [B^T, D]]`` with 2x2 blocks; uses the Schur complement of
    ``A``.  Exact for SPD inputs (A is then invertible).
    """
    a = s[..., :2, :2]
    b = s[..., :2, 2:]
    c = s[..., 2:, :2]
    d = s[..., 2:, 2:]
    a_inv = inv2(a)
    # Schur complement of A: D - C A^-1 B  (2x2)
    schur = d - jnp.einsum("...ij,...jk,...kl->...il", c, a_inv, b)
    schur_inv = inv2(schur)
    aib = jnp.einsum("...ij,...jk->...ik", a_inv, b)   # A^-1 B
    cai = jnp.einsum("...ij,...jk->...ik", c, a_inv)   # C A^-1
    top_left = a_inv + jnp.einsum("...ij,...jk,...kl->...il", aib, schur_inv, cai)
    top_right = -jnp.einsum("...ij,...jk->...ik", aib, schur_inv)
    bot_left = -jnp.einsum("...ij,...jk->...ik", schur_inv, cai)
    top = jnp.concatenate([top_left, top_right], axis=-1)
    bot = jnp.concatenate([bot_left, schur_inv], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def inv2(m: jnp.ndarray) -> jnp.ndarray:
    """Closed-form inverse of a batch of 2x2 matrices."""
    a, b = m[..., 0, 0], m[..., 0, 1]
    c, d = m[..., 1, 0], m[..., 1, 1]
    det = a * d - b * c
    inv_det = 1.0 / det
    row0 = jnp.stack([d * inv_det, -b * inv_det], axis=-1)
    row1 = jnp.stack([-c * inv_det, a * inv_det], axis=-1)
    return jnp.stack([row0, row1], axis=-2)


def update(x: jnp.ndarray, p: jnp.ndarray, z: jnp.ndarray, params: KalmanParams):
    """Measurement update.

    ``y = z - Hx``; ``S = H P H^T + R``; ``K = P H^T S^-1``;
    ``x <- x + K y``; ``P <- (I - K H) P`` (Joseph-free form, as filterpy/SORT).
    """
    y = z - jnp.einsum("ij,...j->...i", params.H, x)
    pht = jnp.einsum("...ij,kj->...ik", p, params.H)           # [..., 7, 4]
    s = jnp.einsum("ij,...jk->...ik", params.H, pht) + params.R  # [..., 4, 4]
    s_inv = inv4_spd(s)
    k = jnp.einsum("...ij,...jk->...ik", pht, s_inv)           # [..., 7, 4]
    x_new = x + jnp.einsum("...ij,...j->...i", k, y)
    ikh = jnp.eye(DIM_X, dtype=p.dtype) - jnp.einsum("...ij,jk->...ik", k, params.H)
    p_new = jnp.einsum("...ij,...jk->...ik", ikh, p)
    return x_new, p_new


def masked_update(x, p, z, mask, params: KalmanParams):
    """Apply ``update`` only where ``mask [...,]`` is True (static shapes)."""
    x_u, p_u = update(x, p, z, params)
    m = mask[..., None]
    x_out = jnp.where(m, x_u, x)
    p_out = jnp.where(mask[..., None, None], p_u, p)
    return x_out, p_out
