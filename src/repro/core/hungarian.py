"""Hungarian algorithm (linear sum assignment) in pure JAX ``lax`` control flow.

The paper uses the Hungarian method in matrix form to match Kalman
predictions to detections.  The cost matrices are tiny (<= ~13x13, paper
Table I), so the right TPU strategy is the one the paper argues for threads:
never split one matrix — batch *many* matrices and solve them in parallel
lanes.  This module is written so the full solver ``vmap``s over a leading
batch axis with static shapes.

Algorithm: shortest-augmenting-path / Jonker-Volgenant variant, O(n^3), the
same scheme scipy's ``linear_sum_assignment`` uses, expressed with
``lax.fori_loop`` (rows) + ``lax.while_loop`` (Dijkstra + augmentation).

Masked / rectangular problems are handled by padding to a fixed ``n x n``
matrix with a large constant ``PAD``: because every pad entry has the *same*
cost, the optimum on the valid ``D x T`` submatrix is preserved and the
number of real-real matches is maximized (PAD dominates any real cost range).
Validated against scipy in ``tests/test_hungarian.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_INF = 1.0e18


def auto_pad_value(cost: jnp.ndarray, valid: jnp.ndarray, n: int) -> jnp.ndarray:
    """Pad cost that (a) always loses to any real match and (b) stays inside
    float32 precision of the real cost range.

    A fixed huge constant (1e6) breaks in float32: reduced costs mix the pad
    scale with the real scale and the real costs quantize away.  Instead use
    ``cmax + n * (cmax - cmin) + 1`` per problem: swapping one real match for
    a pad match then always increases the total, so the solver still
    maximizes the number of real-real matches.
    """
    big = jnp.where(valid, cost, -_INF)
    small = jnp.where(valid, cost, _INF)
    cmax = jnp.maximum(big.max(axis=(-2, -1)), 0.0)
    cmin = jnp.minimum(small.min(axis=(-2, -1)), 0.0)
    return cmax + n * (cmax - cmin) + 1.0


def pad_cost_matrix(cost: jnp.ndarray, row_mask: jnp.ndarray, col_mask: jnp.ndarray,
                    n: int, pad_value=None, pair_mask=None) -> jnp.ndarray:
    """Embed a masked ``[..., R, C]`` cost into an ``[..., n, n]`` padded square
    matrix.  ``pad_value=None`` selects the precision-safe adaptive pad.

    ``pair_mask [..., R, C]`` (optional) marks individual pairs infeasible
    on top of the row/col masks — the hook for class partitioning and the
    Mahalanobis gate (DESIGN.md §10).  Infeasible pairs take the same pad
    value as masked rows/cols, so the solver maximizes the number of
    *feasible* matches; with a class-equality mask the feasible pairs
    decompose into disjoint per-class blocks, making one padded solve
    exactly equivalent to solving each class's sub-problem separately
    (block-diagonal matching in a single lane-batched call).
    """
    r, c = cost.shape[-2], cost.shape[-1]
    assert n >= r and n >= c, (n, r, c)
    valid = row_mask[..., :, None] & col_mask[..., None, :]
    if pair_mask is not None:
        valid = valid & pair_mask
    if pad_value is None:
        pad_value = auto_pad_value(cost, valid, n)
    pad_value = jnp.asarray(pad_value, cost.dtype)[..., None, None]
    out = jnp.broadcast_to(pad_value, cost.shape[:-2] + (n, n)).copy()
    block = jnp.where(valid, cost, pad_value)
    return out.at[..., :r, :c].set(block)


def solve(cost: jnp.ndarray) -> jnp.ndarray:
    """Solve one ``[n, n]`` assignment problem.

    Returns ``col4row [n] int32``: column assigned to each row.  Total cost
    ``cost[arange(n), col4row].sum()`` is minimal.
    """
    n = cost.shape[-1]
    assert cost.shape == (n, n), cost.shape
    cost = cost.astype(jnp.float32)

    def solve_row(cur_row, carry):
        u, v, col4row, row4col = carry
        # --- Dijkstra over columns to find an augmenting path from cur_row ---
        spc = jnp.full((n,), _INF)       # shortest path cost to each column
        path = jnp.full((n,), -1, jnp.int32)  # predecessor row per column
        sr = jnp.zeros((n,), bool)       # scanned rows
        sc = jnp.zeros((n,), bool)       # scanned cols

        def cond(st):
            _i, _min_val, sink, *_ = st
            return sink < 0

        def body(st):
            i, min_val, sink, spc, path, sr, sc = st
            sr = sr.at[i].set(True)
            red = min_val + cost[i, :] - u[i] - v
            upd = (~sc) & (red < spc)
            spc = jnp.where(upd, red, spc)
            path = jnp.where(upd, i, path)
            # pick the cheapest unscanned column (ties broken arbitrarily --
            # any minimum keeps Dijkstra invariants and the optimal cost)
            masked = jnp.where(sc, _INF, spc)
            j = jnp.argmin(masked).astype(jnp.int32)
            min_val = spc[j]
            sc = sc.at[j].set(True)
            free = row4col[j] < 0
            sink = jnp.where(free, j, jnp.int32(-1))
            i = jnp.where(free, i, row4col[j])
            return i, min_val, sink, spc, path, sr, sc

        init = (jnp.int32(cur_row), jnp.float32(0.0), jnp.int32(-1), spc, path, sr, sc)
        _, min_val, sink, spc, path, sr, sc = lax.while_loop(cond, body, init)

        # --- dual updates (scipy rectangular_lsap convention) ---
        u = u.at[cur_row].add(min_val)
        others = sr & (jnp.arange(n) != cur_row)
        u = jnp.where(others, u + min_val - spc[jnp.clip(col4row, 0, n - 1)], u)
        v = jnp.where(sc, v + spc - min_val, v)

        # --- augment along the alternating path back from sink ---
        def aug_cond(st):
            _c4r, _r4c, _j, done = st
            return ~done

        def aug_body(st):
            col4row, row4col, j, _done = st
            i = path[j]
            row4col = row4col.at[j].set(i)
            nxt = col4row[i]
            col4row = col4row.at[i].set(j)
            return col4row, row4col, nxt, i == cur_row

        col4row, row4col, _, _ = lax.while_loop(
            aug_cond, aug_body, (col4row, row4col, sink, jnp.bool_(False)))
        return u, v, col4row, row4col

    u0 = jnp.zeros((n,), jnp.float32)
    v0 = jnp.zeros((n,), jnp.float32)
    c4r0 = jnp.full((n,), -1, jnp.int32)
    r4c0 = jnp.full((n,), -1, jnp.int32)
    _, _, col4row, _ = lax.fori_loop(0, n, solve_row, (u0, v0, c4r0, r4c0))
    return col4row


def solve_batched(cost: jnp.ndarray) -> jnp.ndarray:
    """``[..., n, n] -> [..., n]`` — vmapped over all leading axes."""
    batch = cost.shape[:-2]
    n = cost.shape[-1]
    flat = cost.reshape((-1, n, n))
    out = jax.vmap(solve)(flat)
    return out.reshape(batch + (n,))


def solve_masked(cost: jnp.ndarray, row_mask: jnp.ndarray, col_mask: jnp.ndarray,
                 n: int, pair_mask=None) -> jnp.ndarray:
    """Masked rectangular assignment.

    Returns ``col4row [..., n]`` where entry ``i`` is the assigned column for
    row ``i``, or an arbitrary pad column when row ``i`` is invalid or was
    matched to padding.  Callers must re-validate matches (e.g. by IoU gate);
    SORT does this anyway.  ``pair_mask [..., R, C]`` marks individual
    pairs infeasible (see :func:`pad_cost_matrix`) — an infeasible
    assignment can survive only as a padding match, which the caller's
    gate discards.
    """
    padded = pad_cost_matrix(cost, row_mask, col_mask, n, pair_mask=pair_mask)
    return solve_batched(padded)


def solve_masked_lane(cost: jnp.ndarray, row_mask: jnp.ndarray,
                      col_mask: jnp.ndarray, n: int,
                      pair_mask=None) -> jnp.ndarray:
    """:func:`solve_masked` for the kernels' *lane layout* (DESIGN.md §2):
    the batch lives on the trailing lane axes, the tiny matrix on the
    leading ones — ``cost [R, C, *lanes]``, ``row_mask [R, *lanes]``,
    ``col_mask [C, *lanes]`` -> ``col4row [n, *lanes] int32``.

    This is the standalone lane-level solver API for the fused frame
    step's layout: the ``[D, T, S]`` IoU cost built from the resident
    ``[7, B]`` state solves one tiny problem per lane, never splitting a
    matrix — the paper's batching argument.  Per-lane results are
    bit-identical to :func:`solve_masked` on the transposed batch (the
    same per-problem op sequence, only the batch axis moves;
    ``tests/test_hungarian.py`` locks this down), which is what lets the
    fused-Hungarian engine path (``core.association.associate_lane``, the
    same transpose + the same batch core) match the unfused one exactly.
    """
    r, c = cost.shape[0], cost.shape[1]
    lanes = cost.shape[2:]
    cost_b = jnp.moveaxis(cost.reshape(r, c, -1), -1, 0)       # [L, R, C]
    rm_b = jnp.moveaxis((row_mask > 0).reshape(r, -1), -1, 0)  # [L, R]
    cm_b = jnp.moveaxis((col_mask > 0).reshape(c, -1), -1, 0)  # [L, C]
    pm_b = (None if pair_mask is None
            else jnp.moveaxis(pair_mask.reshape(r, c, -1), -1, 0))
    out = solve_masked(cost_b, rm_b, cm_b, n, pair_mask=pm_b)  # [L, n]
    return jnp.moveaxis(out, 0, -1).reshape((n,) + lanes)
