"""Detection <-> tracker association (paper §II-B, §III step "Assign").

Builds the IoU cost matrix between Kalman-predicted boxes and the frame's
detections, solves the assignment with the batched Hungarian solver, and
gates matches below the IoU threshold — exactly the SORT recipe
(``associate_detections_to_trackers`` in Bewley's reference code), but fully
batched over streams with static shapes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import bbox, hungarian


class Association(NamedTuple):
    """All masks are aligned to the padded det/tracker slot axes.

    ``det_to_trk [..., D] int32``: matched tracker slot per detection (or -1).
    ``trk_to_det [..., T] int32``: matched detection per tracker slot (or -1).
    ``matched_det [..., D] bool``  / ``matched_trk [..., T] bool``.
    ``unmatched_det [..., D] bool``: valid detections that should seed births.
    ``unmatched_trk [..., T] bool``: alive trackers that missed this frame.
    """

    det_to_trk: jnp.ndarray
    trk_to_det: jnp.ndarray
    matched_det: jnp.ndarray
    matched_trk: jnp.ndarray
    unmatched_det: jnp.ndarray
    unmatched_trk: jnp.ndarray
    iou: jnp.ndarray  # [..., D, T] full IoU matrix (for metrics / debugging)


def associate(det_boxes: jnp.ndarray, det_mask: jnp.ndarray,
              trk_boxes: jnp.ndarray, trk_mask: jnp.ndarray,
              iou_threshold: float = 0.3,
              iou_fn=None, score=None, feasible=None) -> Association:
    """SORT association for a batch of streams.

    det_boxes ``[..., D, 4]`` xyxy; trk_boxes ``[..., T, 4]`` xyxy (predicted);
    masks flag valid rows.  ``iou_fn`` allows swapping in the Pallas kernel.
    ``score`` / ``feasible`` (optional, ``[..., D, T]``) plug in a
    composed association cost (``core.cost``, DESIGN.md §10): the solve
    runs on ``score`` while the IoU threshold still gates post-solve, and
    ``feasible=False`` pairs are masked out of the solve entirely.
    """
    iou = (iou_fn or bbox.iou_matrix)(det_boxes, trk_boxes)  # [..., D, T]
    return associate_from_iou(iou, det_mask, trk_mask, iou_threshold,
                              score=score, feasible=feasible)


def _all_unmatched(iou: jnp.ndarray, det_mask: jnp.ndarray,
                   trk_mask: jnp.ndarray) -> Association:
    """Degenerate frame (``D == 0`` or ``T == 0``): nothing can match, and
    the gather/scatter inversion below would index into a size-0 axis."""
    d, t = iou.shape[-2], iou.shape[-1]
    batch = iou.shape[:-2]
    return Association(
        det_to_trk=jnp.full(batch + (d,), -1, jnp.int32),
        trk_to_det=jnp.full(batch + (t,), -1, jnp.int32),
        matched_det=jnp.zeros(batch + (d,), bool),
        matched_trk=jnp.zeros(batch + (t,), bool),
        unmatched_det=jnp.broadcast_to(det_mask, batch + (d,)),
        unmatched_trk=jnp.broadcast_to(trk_mask, batch + (t,)),
        iou=iou)


def associate_from_iou(iou: jnp.ndarray, det_mask: jnp.ndarray,
                       trk_mask: jnp.ndarray,
                       iou_threshold: float = 0.3,
                       score=None, feasible=None) -> Association:
    """The solve + gate + invert core of :func:`associate`, starting from a
    precomputed IoU matrix ``[..., D, T]`` (batch leading).

    ``score [..., D, T]`` (optional) replaces IoU as the solver's
    maximization objective (the composed cost of ``core.cost``); the IoU
    threshold still gates post-solve.  ``feasible [..., D, T]`` (optional)
    hard-masks pairs out of the solve (class partition / Mahalanobis
    gate) *and* out of the gate, so an infeasible pair can never match.
    With both ``None`` this is byte-for-byte the original IoU-only path.
    """
    d, t = iou.shape[-2], iou.shape[-1]
    if d == 0 or t == 0:  # static shapes: zero tracker slots / detections
        return _all_unmatched(iou, det_mask, trk_mask)
    n = max(d, t)
    cost = -(iou if score is None else score)
    col4row = hungarian.solve_masked(cost, det_mask, trk_mask, n,
                                     pair_mask=feasible)  # [..., n]
    return _gate_and_invert(iou, det_mask, trk_mask, col4row, iou_threshold,
                            feasible=feasible)


def _gate_and_invert(iou, det_mask, trk_mask, col4row,
                     iou_threshold, feasible=None) -> Association:
    """Shared gate + inversion: validate each detection's solver column
    (in-range, valid tracker, IoU above threshold, pair feasible) and
    scatter the matching into tracker-major form.  Both layouts' entry
    points funnel here, so their match decisions are identical by
    construction."""
    d, t = iou.shape[-2], iou.shape[-1]
    det_idx = jnp.arange(d)
    assigned_col = col4row[..., :d]                        # [..., D]
    in_range = assigned_col < t
    safe_col = jnp.where(in_range, assigned_col, 0)
    pair_iou = jnp.take_along_axis(
        iou, safe_col[..., None], axis=-1)[..., 0]         # iou of (det, its col)
    pair_trk_valid = jnp.take_along_axis(
        jnp.broadcast_to(trk_mask, iou.shape[:-2] + (t,)), safe_col, axis=-1)
    good = (det_mask
            & in_range
            & pair_trk_valid
            & (pair_iou >= iou_threshold))
    if feasible is not None:
        pair_feasible = jnp.take_along_axis(
            feasible, safe_col[..., None], axis=-1)[..., 0]
        good = good & pair_feasible

    det_to_trk = jnp.where(good, safe_col, -1).astype(jnp.int32)
    # invert: tracker slot -> detection.  Scatter each good det's index into
    # its tracker slot; invalid matches go to an overflow slot that is sliced
    # off.  (The Hungarian solution is a matching, so no slot collides.)
    batch = iou.shape[:-2]
    overflow = jnp.full(batch + (t + 1,), -1, jnp.int32)
    scatter_idx = jnp.where(good, safe_col, t)
    src = jnp.broadcast_to(det_idx, det_to_trk.shape).astype(jnp.int32)
    trk_to_det = _scatter_last(overflow, scatter_idx, src)[..., :t]

    matched_det = good
    matched_trk = trk_to_det >= 0
    unmatched_det = det_mask & ~matched_det
    unmatched_trk = trk_mask & ~matched_trk
    return Association(det_to_trk, trk_to_det, matched_det, matched_trk,
                       unmatched_det, unmatched_trk, iou)


def associate_lane(iou: jnp.ndarray, det_mask: jnp.ndarray,
                   trk_mask: jnp.ndarray, iou_threshold: float = 0.3,
                   score=None, feasible=None):
    """Hungarian association on the kernels' lane layout (DESIGN.md §6).

    ``iou [D, T, *lanes]``, ``det_mask [D, *lanes]``, ``trk_mask
    [T, *lanes]`` (bool or 0/1 float) -> ``(trk_to_det [T, *lanes] int32,
    matched_det [D, *lanes] bool)`` — the inverted form the fused SORT
    frame step consumes (the same pair ``core.greedy.greedy_assign_lane``
    returns, so the two association modes are drop-in interchangeable).
    ``score`` / ``feasible`` (optional, ``[D, T, *lanes]``) carry the
    composed association cost exactly as in :func:`associate_from_iou`.

    One transpose to the batch layout, then the identical
    solve + gate + invert core as :func:`associate` (the per-lane JV
    problems are what :func:`repro.core.hungarian.solve_masked_lane`
    exposes standalone), so gating and tie-breaking are *identical* to
    the non-fused engine path — the fused-Hungarian bit-parity guarantee
    of ``tests/test_oracle_parity.py``.
    """
    d, t = iou.shape[0], iou.shape[1]
    lanes = iou.shape[2:]
    if d == 0 or t == 0:
        return (jnp.full((t,) + lanes, -1, jnp.int32),
                jnp.zeros((d,) + lanes, bool))
    iou_b = jnp.moveaxis(iou.reshape(d, t, -1), -1, 0)          # [L, D, T]
    dm_b = jnp.moveaxis((det_mask > 0).reshape(d, -1), -1, 0)   # [L, D]
    tm_b = jnp.moveaxis((trk_mask > 0).reshape(t, -1), -1, 0)   # [L, T]
    sc_b = (None if score is None
            else jnp.moveaxis(score.reshape(d, t, -1), -1, 0))
    fe_b = (None if feasible is None
            else jnp.moveaxis(feasible.reshape(d, t, -1), -1, 0))
    a = associate_from_iou(iou_b, dm_b, tm_b, iou_threshold,
                           score=sc_b, feasible=fe_b)
    trk_to_det = jnp.moveaxis(a.trk_to_det, 0, -1).reshape((t,) + lanes)
    matched_det = jnp.moveaxis(a.matched_det, 0, -1).reshape((d,) + lanes)
    return trk_to_det, matched_det


def _scatter_last(buf: jnp.ndarray, idx: jnp.ndarray, src: jnp.ndarray) -> jnp.ndarray:
    """Scatter ``src`` into ``buf`` along the last axis at ``idx`` (batched)."""
    # one-hot matmul-free scatter: use take_along_axis-compatible at[] with
    # explicit batch iota via vmapped scatter -- jnp supports batched .at when
    # we flatten the batch.
    b = buf.reshape((-1, buf.shape[-1]))
    i = idx.reshape((-1, idx.shape[-1]))
    s = src.reshape((-1, src.shape[-1]))
    rows = jnp.arange(b.shape[0])[:, None]
    out = b.at[rows, i].set(s)
    return out.reshape(buf.shape)
