"""SORT — Simple Online and Real-time Tracking, batched over streams.

Implements paper Algorithm 1 / Fig. 2's ``Update`` function as a single
jit-compiled, static-shape step over a *batch* of independent video streams:
the TPU realization of the paper's throughput-scaling result (one OpenMP
worker per stream -> one vector lane per stream; see DESIGN.md §2).

Per frame (paper Fig. 2):
  1. Kalman-predict every live tracker          (§ "Predict",   AI 2.4)
  2. IoU cost + Hungarian assignment + gating   (§ "Assign",    AI 1.5)
  3. Kalman-update matched trackers             (§ "Update",    AI 18)
  4. age/kill unmatched trackers, birth new trackers from unmatched
     detections                                 (§ "Create new")
  5. emit confirmed tracks                      (§ "Prepare output")

Lifecycle constants follow Bewley's reference implementation
(max_age=1, min_hits=3, iou_threshold=0.3).

Two execution paths (selected by ``SortConfig.use_kernels``):

* ``False`` — legacy per-phase path: engine-layout state
  (``[S, T, ...]``), injectable per-phase kernels.
* ``True`` — lane-persistent fused path: state is converted once per
  ``run()`` to :class:`LaneSortState` (the Pallas kernels' lane layout,
  DESIGN.md §2.2) and every frame is a single fused
  predict/IoU/assign/update dispatch (``repro.kernels.frame``).

Both paths run either association algorithm (``SortConfig.assoc``,
DESIGN.md §6): ``"hungarian"`` — the paper's optimal assignment, the
default — or ``"greedy"`` best-first matching.  On the fused path the
Hungarian JV solve runs as a jitted lane-batched stage feeding the single
kernel dispatch (``kernels/ops.py::frame_step``), so ``use_kernels=True``
no longer trades the paper's algorithm for speed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import association, bbox, greedy, kalman, slots
from . import cost as cost_mod


@dataclasses.dataclass(frozen=True)
class SortConfig:
    max_trackers: int = 16     # slot capacity T (>= max objects/frame; Table I max is 13)
    max_detections: int = 16   # padded detections per frame D
    iou_threshold: float = 0.3
    max_age: int = 1
    min_hits: int = 3
    dtype: str = "float32"
    # association algorithm (DESIGN.md §6): "hungarian" — optimal
    # assignment, the paper's algorithm and the default — or "greedy"
    # best-first matching (cheaper, near-identical on sparse scenes).
    # Honored by both execution paths; on the fused path the Hungarian
    # solve runs as a jitted lane-batched stage feeding the kernel.
    assoc: str = "hungarian"
    # True -> lane-persistent fused frame path: state stays in the kernels'
    # lane layout across the whole run and every frame is one fused
    # predict/IoU/assign/update dispatch (repro.kernels.frame), with the
    # association algorithm chosen by `assoc` above.
    use_kernels: bool = False
    # tracker-lane block for the fused path; streams per kernel block is
    # block_b // max_trackers (DESIGN.md §2.3) — the default gives a full
    # 128-lane stream block at T=16, matching the TPU lane tile.
    block_b: int = 2048
    # True -> chunk-resident megakernel (DESIGN.md §9): run_chunk_ragged
    # executes a whole planned serving chunk (F frames) as ONE pallas_call
    # with the frame loop on the kernel grid and lane state VMEM-resident
    # across the chunk — dispatches per chunk drop from F to 1, outputs
    # stay bit-identical.  Requires use_kernels=True (it is the fused lane
    # path at chunk granularity).
    chunk_kernel: bool = False
    # pluggable association cost (core.cost, DESIGN.md §10): the default
    # pure-IoU spec keeps every path byte-identical to the pre-cost
    # engine; other specs add the Mahalanobis gate and/or an appearance-
    # embedding term, and require det_embed inputs when embed_dim > 0.
    cost: cost_mod.CostSpec = cost_mod.IOU
    # > 1 partitions association by object class: cross-class pairs are
    # infeasible (cost-matrix masking), so Hungarian/greedy solve the
    # block-diagonal per-class problem in one lane-batched call, and the
    # engine consumes/propagates det_class inputs (tracks carry their
    # class through lifecycle, recycling, and SortOutput.cls).
    num_classes: int = 1


class SortState(NamedTuple):
    x: jnp.ndarray        # [S, T, 7]  Kalman means
    p: jnp.ndarray        # [S, T, 7, 7] covariances
    pool: slots.SlotPool  # [S, T] lifecycle
    frame_count: jnp.ndarray  # [S] int32
    # [S, T, E] per-track appearance embeddings (DESIGN.md §10); E =
    # config.cost.embed_dim, a zero-size array when the cost has no
    # appearance term.  Last field so positional construction of the
    # pre-embed fields stays valid in older call sites/tests.
    embed: jnp.ndarray = None


class LaneSortState(NamedTuple):
    """Persistent lane-layout engine state (DESIGN.md §2.2).

    The tracker batch ``B = T * S_pad`` lives on the TPU lane dimension,
    **tracker-slot major**: lane ``b = t * S_pad + s``, so
    ``x.reshape(7, T, S_pad)`` is a free (row-major) view with streams on
    lanes — exactly the fused frame kernel's operand layout.  ``S_pad`` is
    the stream count padded to the kernel's stream block; padded streams
    carry ``alive=False`` and an identity-friendly covariance so every
    lane stays finite through predict/update.

    ``pool`` fields are lane-major ``[T, S_pad]`` (``slots.transpose_pool``
    of the engine layout); ``frame_count [S_pad]``.

    Unlike :class:`SortState`, which round-trips ``[S, T, 7, 7]`` through
    reshape+pad+transpose around every kernel dispatch, this state is
    created once per ``run()`` and converted back only at the API boundary.
    """

    x: jnp.ndarray        # [7, B]   lane-major Kalman means
    p: jnp.ndarray        # [49, B]  lane-major covariances (row-major 7x7)
    pool: slots.SlotPool  # [T, S_pad] lane-major lifecycle
    frame_count: jnp.ndarray  # [S_pad] int32
    # [E, B] lane-major appearance embeddings (zero-size when unused);
    # reshapes to [E, T, S_pad] exactly like x (same lane ordering)
    embed: jnp.ndarray = None


def _pad_streams(s: int, block_s: int) -> int:
    return -(-s // block_s) * block_s


def lane_state_of(state: SortState, block_s: int) -> LaneSortState:
    """Engine layout -> persistent lane layout (exact; inverse of
    :func:`sort_state_of` for any ``S``, multiple of ``block_s`` or not)."""
    s, t = state.x.shape[0], state.x.shape[1]
    sp = _pad_streams(s, block_s)
    grow = sp - s
    x = jnp.pad(state.x, ((0, grow), (0, 0), (0, 0)))
    p = jnp.pad(state.p, ((0, grow), (0, 0), (0, 0), (0, 0)),
                constant_values=1.0)  # keep padded innovation S invertible
    pool = state.pool._replace(
        alive=jnp.pad(state.pool.alive, ((0, grow), (0, 0))),
        age=jnp.pad(state.pool.age, ((0, grow), (0, 0))),
        hits=jnp.pad(state.pool.hits, ((0, grow), (0, 0))),
        hit_streak=jnp.pad(state.pool.hit_streak, ((0, grow), (0, 0))),
        time_since_update=jnp.pad(state.pool.time_since_update,
                                  ((0, grow), (0, 0))),
        uid=jnp.pad(state.pool.uid, ((0, grow), (0, 0)), constant_values=-1),
        cls=jnp.pad(state.pool.cls, ((0, grow), (0, 0)), constant_values=-1),
        next_uid=jnp.pad(state.pool.next_uid, ((0, grow),),
                         constant_values=1),
    )
    embed = jnp.pad(state.embed, ((0, grow), (0, 0), (0, 0)))
    e = embed.shape[-1]
    return LaneSortState(
        x=x.transpose(2, 1, 0).reshape(kalman.DIM_X, t * sp),
        p=p.reshape(sp, t, 49).transpose(2, 1, 0).reshape(49, t * sp),
        pool=slots.transpose_pool(pool),
        frame_count=jnp.pad(state.frame_count, ((0, grow),)),
        embed=embed.transpose(2, 1, 0).reshape(e, t * sp),
    )


def sort_state_of(lane: LaneSortState, num_streams: int) -> SortState:
    """Persistent lane layout -> engine layout (drops stream padding)."""
    t = lane.pool.alive.shape[0]
    sp = lane.frame_count.shape[0]
    s = num_streams
    x = lane.x.reshape(kalman.DIM_X, t, sp)[..., :s].transpose(2, 1, 0)
    p = (lane.p.reshape(49, t, sp)[..., :s].transpose(2, 1, 0)
         .reshape(s, t, kalman.DIM_X, kalman.DIM_X))
    pool = slots.transpose_pool(lane.pool)
    pool = pool._replace(
        **{f: getattr(pool, f)[:s]
           for f in ("alive", "age", "hits", "hit_streak",
                     "time_since_update", "uid", "cls")},
        next_uid=pool.next_uid[:s])
    e = lane.embed.shape[0]
    embed = lane.embed.reshape(e, t, sp)[..., :s].transpose(2, 1, 0)
    return SortState(x, p, pool, lane.frame_count[:s], embed)


# SlotPool fields carrying a slot axis (next_uid is per-stream only)
_POOL_SLOT_FIELDS = ("alive", "age", "hits", "hit_streak",
                     "time_since_update", "uid", "cls")


def _select_pool(slot_mask: jnp.ndarray, stream_mask: jnp.ndarray,
                 new: slots.SlotPool, old: slots.SlotPool) -> slots.SlotPool:
    """Per-stream pool select: ``new`` where the mask holds, else ``old``.
    ``slot_mask`` broadcasts over the slot fields (either orientation),
    ``stream_mask`` over the per-stream uid counter."""
    return new._replace(
        **{f: jnp.where(slot_mask, getattr(new, f), getattr(old, f))
           for f in _POOL_SLOT_FIELDS},
        next_uid=jnp.where(stream_mask, new.next_uid, old.next_uid))


def _reset_pool(pool: slots.SlotPool, reset_lane_major: jnp.ndarray,
                reset_streams_: jnp.ndarray,
                uid_start: int = 1) -> slots.SlotPool:
    """Masked pool re-init (``slots.init_pool``'s values, applied in
    place): ``reset_lane_major`` broadcasts over the slot fields,
    ``reset_streams_`` over the per-stream uid counter."""
    zero = jnp.zeros((), jnp.int32)
    return slots.SlotPool(
        alive=jnp.where(reset_lane_major, False, pool.alive),
        age=jnp.where(reset_lane_major, zero, pool.age),
        hits=jnp.where(reset_lane_major, zero, pool.hits),
        hit_streak=jnp.where(reset_lane_major, zero, pool.hit_streak),
        time_since_update=jnp.where(reset_lane_major, zero,
                                    pool.time_since_update),
        uid=jnp.where(reset_lane_major, -1, pool.uid),
        cls=jnp.where(reset_lane_major, -1, pool.cls),
        next_uid=jnp.where(reset_streams_, uid_start, pool.next_uid),
    )


def reset_streams(state: SortState, reset: jnp.ndarray,
                  uid_start: int = 1) -> SortState:
    """Masked :meth:`SortEngine.init`: streams with ``reset=True`` return
    to the freshly-initialised state (zero Kalman means, initial
    covariance, empty pool, ``next_uid=uid_start``, ``frame_count=0``)
    while every other stream is untouched.  This is how the ragged
    scheduler recycles an engine-layout lane for a newly admitted
    sequence (DESIGN.md §3).
    """
    r1 = reset[:, None]                                          # [S, 1]
    p0 = kalman.initial_covariance(state.p.dtype)
    return SortState(
        x=jnp.where(r1[..., None], 0.0, state.x),
        p=jnp.where(r1[..., None, None], p0, state.p),
        pool=_reset_pool(state.pool, r1, reset, uid_start),
        frame_count=jnp.where(reset, 0, state.frame_count),
        embed=jnp.where(r1[..., None], 0.0, state.embed),
    )


def reset_lanes(lane: LaneSortState, reset: jnp.ndarray,
                uid_start: int = 1) -> LaneSortState:
    """:func:`reset_streams` for the persistent lane layout: ``reset [S]``
    bool (``S <= S_pad``; padded with False like ``lane_step``'s
    ``stream_active``) masks whole streams (every tracker slot of the
    lane) back to the init state without leaving the lane layout.
    """
    t = lane.pool.alive.shape[0]
    sp = lane.frame_count.shape[0]
    if reset.shape[0] != sp:
        reset = jnp.pad(reset, ((0, sp - reset.shape[0]),))
    r_lane = reset[None, :]                                      # [1, Sp]
    x3 = lane.x.reshape(kalman.DIM_X, t, sp)
    p3 = lane.p.reshape(49, t, sp)
    p0 = kalman.initial_covariance(lane.p.dtype).reshape(49)
    x3 = jnp.where(r_lane[None], 0.0, x3)
    p3 = jnp.where(r_lane[None], p0[:, None, None], p3)
    e = lane.embed.shape[0]
    e3 = jnp.where(r_lane[None], 0.0, lane.embed.reshape(e, t, sp))
    return LaneSortState(
        x=x3.reshape(kalman.DIM_X, t * sp),
        p=p3.reshape(49, t * sp),
        pool=_reset_pool(lane.pool, r_lane, reset, uid_start),
        frame_count=jnp.where(reset, 0, lane.frame_count),
        embed=e3.reshape(e, t * sp),
    )


def reset_ragged(state, reset: jnp.ndarray, uid_start: int = 1):
    """Dispatch the masked re-init by state layout (scheduler glue)."""
    if isinstance(state, LaneSortState):
        return reset_lanes(state, reset, uid_start)
    return reset_streams(state, reset, uid_start)


def chunk_state_of(lane: LaneSortState):
    """Persistent lane layout -> the megakernel's flat numeric
    :class:`repro.kernels.ref.ChunkState` (DESIGN.md §9): free reshapes of
    ``x``/``p`` into the ``[*, T, S_pad]`` view, lifecycle cast to int32,
    per-stream counters given a unit sublane axis.  Exact inverse of
    :func:`lane_state_of_chunk`."""
    from repro.kernels import ref as kref

    t = lane.pool.alive.shape[0]
    sp = lane.frame_count.shape[0]
    e = lane.embed.shape[0]
    return kref.ChunkState(
        x=lane.x.reshape(kalman.DIM_X, t, sp),
        p=lane.p.reshape(49, t, sp),
        alive=lane.pool.alive.astype(jnp.int32),
        age=lane.pool.age, hits=lane.pool.hits,
        hit_streak=lane.pool.hit_streak,
        time_since_update=lane.pool.time_since_update,
        uid=lane.pool.uid, cls=lane.pool.cls,
        next_uid=lane.pool.next_uid[None, :],
        frame_count=lane.frame_count[None, :],
        embed=lane.embed.reshape(e, t, sp))


def lane_state_of_chunk(cs) -> LaneSortState:
    """The megakernel's :class:`~repro.kernels.ref.ChunkState` back to the
    persistent lane layout (exact inverse of :func:`chunk_state_of`)."""
    t = cs.alive.shape[0]
    sp = cs.frame_count.shape[1]
    e = cs.embed.shape[0]
    pool = slots.SlotPool(
        alive=cs.alive > 0, age=cs.age, hits=cs.hits,
        hit_streak=cs.hit_streak, time_since_update=cs.time_since_update,
        uid=cs.uid, cls=cs.cls, next_uid=cs.next_uid[0])
    return LaneSortState(x=cs.x.reshape(kalman.DIM_X, t * sp),
                         p=cs.p.reshape(49, t * sp), pool=pool,
                         frame_count=cs.frame_count[0],
                         embed=cs.embed.reshape(e, t * sp))


def resize_streams(state: SortState, num_streams: int) -> SortState:
    """Migrate an engine-layout state between stream budgets (DESIGN.md
    §8): the state-level half of elastic lane budgets.

    * **grow** — append streams and run them through the masked re-init
      (:func:`reset_streams` with the tail selected), so every new stream
      is bit-identical to a freshly ``init``-ed one: zero means, initial
      covariance, empty pool, fresh uid namespace, ``frame_count=0``.
    * **shrink** — drop the trailing streams.  The caller owns the drain
      protocol: the scheduler only shrinks once the evacuating lanes hold
      no live sequence, so nothing observable is ever sliced away.

    Kept streams are untouched bit for bit in both directions — a lane
    mid-sequence survives the migration exactly, which is what makes an
    elastic run bit-identical to a fixed-budget run.
    """
    s = state.frame_count.shape[0]
    if num_streams < 1:
        raise ValueError(f"num_streams must be >= 1, got {num_streams}")
    if num_streams == s:
        return state
    if num_streams < s:
        return SortState(
            x=state.x[:num_streams], p=state.p[:num_streams],
            pool=slots.resize_pool(state.pool, num_streams),
            frame_count=state.frame_count[:num_streams],
            embed=state.embed[:num_streams])
    grow = num_streams - s
    wide = SortState(
        x=jnp.pad(state.x, ((0, grow), (0, 0), (0, 0))),
        p=jnp.pad(state.p, ((0, grow), (0, 0), (0, 0), (0, 0))),
        pool=slots.resize_pool(state.pool, num_streams),
        frame_count=jnp.pad(state.frame_count, ((0, grow),)),
        embed=jnp.pad(state.embed, ((0, grow), (0, 0), (0, 0))))
    # masked re-init of exactly the appended tail: the padded x/p above are
    # placeholders; reset_streams writes the true init values (initial
    # covariance included), reusing the scheduler's recycling primitive.
    return reset_streams(wide, jnp.arange(num_streams) >= s)


class SortOutput(NamedTuple):
    boxes: jnp.ndarray    # [S, T, 4] xyxy of every slot (post update/birth)
    uid: jnp.ndarray      # [S, T] track id, -1 if dead
    emit: jnp.ndarray     # [S, T] bool — confirmed tracks to report this frame
    matched_det: jnp.ndarray  # [S, D] bool (for metrics)
    # [S, T] int32 object class per slot (-1 if dead / single-class run);
    # last field with a default so positional construction of the
    # pre-multiclass fields stays valid in older call sites/tests.
    cls: jnp.ndarray = None


class SortEngine:
    """Batched SORT over ``S`` independent streams.

    ``predict_fn(x, p) -> (x, p)`` / ``update_fn(x, p, z, mask) -> (x, p)`` /
    ``iou_fn(a, b) -> iou`` are injection points for Pallas kernels
    (``repro.kernels.ops``); defaults are the pure-jnp reference path so the
    engine runs identically on CPU.
    """

    def __init__(self, config: SortConfig,
                 predict_fn: Optional[Callable] = None,
                 update_fn: Optional[Callable] = None,
                 iou_fn: Optional[Callable] = None,
                 assoc_fn: Optional[Callable] = None):
        if config.assoc not in ("hungarian", "greedy"):
            raise ValueError(
                f"SortConfig.assoc must be 'hungarian' or 'greedy', "
                f"got {config.assoc!r}")
        if config.use_kernels and (predict_fn or update_fn or iou_fn
                                   or assoc_fn):
            raise ValueError(
                "use_kernels=True runs the fused lane-persistent frame "
                "kernel; per-phase injections only apply to the non-fused "
                "path (set use_kernels=False).")
        if config.chunk_kernel and not config.use_kernels:
            raise ValueError(
                "chunk_kernel=True is the chunk-resident megakernel over "
                "the fused lane path (DESIGN.md §9); it requires "
                "use_kernels=True.")
        if config.num_classes < 1:
            raise ValueError(
                f"num_classes must be >= 1, got {config.num_classes}")
        if assoc_fn is not None and not (config.cost.is_iou_only
                                         and config.num_classes == 1):
            raise ValueError(
                "assoc_fn injection bypasses the engine's cost composition; "
                "it only applies to the default single-class IoU config "
                "(cost=IOU, num_classes=1).")
        self.config = config
        self.params = kalman.KalmanParams.default(jnp.dtype(config.dtype))
        # stream padding only buys anything on TPU, where it must match the
        # fused kernel's lane-block grid; the CPU oracle path has no grid,
        # so pad nothing and waste no lanes.
        self._block_s = (max(1, config.block_b // max(1, config.max_trackers))
                         if jax.default_backend() == "tpu" else 1)
        self._predict = predict_fn or (lambda x, p: kalman.predict(x, p, self.params))
        self._update = update_fn or (
            lambda x, p, z, m: kalman.masked_update(x, p, z, m, self.params))
        self._iou = iou_fn or bbox.iou_matrix
        if assoc_fn is not None:          # explicit injection wins
            self._assoc = assoc_fn
        elif config.assoc == "greedy":
            self._assoc = greedy.greedy_iou_fn_for_engine(config.iou_threshold)
        else:
            self._assoc = association.associate

    # ------------------------------------------------------------------ state
    def init(self, num_streams: int) -> SortState:
        cfg = self.config
        dt = jnp.dtype(cfg.dtype)
        return SortState(
            x=jnp.zeros((num_streams, cfg.max_trackers, kalman.DIM_X), dt),
            p=jnp.broadcast_to(kalman.initial_covariance(dt),
                               (num_streams, cfg.max_trackers,
                                kalman.DIM_X, kalman.DIM_X)).copy(),
            pool=slots.init_pool((num_streams,), cfg.max_trackers),
            frame_count=jnp.zeros((num_streams,), jnp.int32),
            embed=jnp.zeros((num_streams, cfg.max_trackers,
                             cfg.cost.embed_dim), dt),
        )

    # ------------------------------------------------------------------- step
    def step(self, state: SortState, det_boxes: jnp.ndarray,
             det_mask: jnp.ndarray, det_class: Optional[jnp.ndarray] = None,
             det_embed: Optional[jnp.ndarray] = None,
             ) -> tuple[SortState, SortOutput]:
        """One frame for every stream.

        ``det_boxes [S, D, 4]`` xyxy, ``det_mask [S, D]``.  ``det_class
        [S, D] int32`` / ``det_embed [S, D, E]`` (optional) feed the
        pluggable association cost (DESIGN.md §10): required when
        ``config.num_classes > 1`` / ``config.cost.embed_dim > 0``.
        """
        self._check_cost_inputs(det_class, det_embed)
        if self.config.use_kernels:
            # boundary convenience: single frames convert both ways; the
            # resident fast path is run(), which converts once per video.
            lane, out = self.lane_step(
                lane_state_of(state, self._block_s), det_boxes, det_mask,
                det_class=det_class, det_embed=det_embed)
            return sort_state_of(lane, det_boxes.shape[0]), out

        cfg = self.config
        x, p, pool = state.x, state.p, state.pool

        # 1. predict (all slots; dead slots are ignored downstream)
        x, p = self._predict(x, p)
        trk_boxes = bbox.z_to_xyxy(x[..., :4])

        # 2. associate (config.assoc: Hungarian by default; injectable)
        if cfg.cost.is_iou_only and cfg.num_classes == 1:
            assoc = self._assoc(det_boxes, det_mask, trk_boxes,
                                pool.alive, cfg.iou_threshold,
                                iou_fn=self._iou)
        else:
            # composed cost (DESIGN.md §10): score/feasible feed the same
            # solve + gate + invert core the default path uses
            iou = self._iou(det_boxes, trk_boxes)
            score, feasible = cost_mod.score_and_feasible_batch(
                iou, cfg.cost, num_classes=cfg.num_classes,
                det_class=det_class, trk_cls=pool.cls,
                det_embed=det_embed, trk_embed=state.embed,
                z_det=(bbox.xyxy_to_z(det_boxes).astype(x.dtype)
                       if cfg.cost.uses_maha else None),
                x_pred=x if cfg.cost.uses_maha else None,
                p4_pred=p[..., :4, :4] if cfg.cost.uses_maha else None)
            from_iou = (greedy.greedy_associate_from_iou
                        if cfg.assoc == "greedy"
                        else association.associate_from_iou)
            assoc = from_iou(iou, det_mask, pool.alive, cfg.iou_threshold,
                             score=score, feasible=feasible)

        # 3. update matched trackers with their detection's observation
        safe_det = jnp.where(assoc.trk_to_det >= 0, assoc.trk_to_det, 0)
        z_all = bbox.xyxy_to_z(det_boxes)                     # [S, D, 4]
        z_trk = jnp.take_along_axis(z_all, safe_det[..., None], axis=-2)
        x, p = self._update(x, p, z_trk.astype(x.dtype), assoc.matched_trk)

        # 4a. age & kill
        pool = slots.tick(pool, assoc.matched_trk, cfg.max_age)

        # 4b. births from unmatched detections into free slots
        slot_for = slots.assign_slots(~pool.alive, assoc.unmatched_det)
        pool = slots.birth(pool, slot_for, det_class=det_class)
        z_det = z_all.astype(x.dtype)
        x, p = _scatter_births(x, p, z_det, slot_for, jnp.dtype(cfg.dtype))

        # 4c. appearance embeddings: matched tracks take their matched
        # detection's embedding, born tracks their claiming detection's
        # (the same replace discipline as the lane/chunk paths)
        embed = state.embed
        if cfg.cost.uses_embed:
            t = cfg.max_trackers
            de = det_embed.astype(embed.dtype)
            m_e = jnp.take_along_axis(de, safe_det[..., None], axis=-2)
            embed = jnp.where(assoc.matched_trk[..., None], m_e, embed)
            target = jnp.where(slot_for >= 0, slot_for, t)  # overflow slot
            ee = jnp.concatenate([embed, embed[:, :1]], axis=1)
            rows = jnp.arange(embed.shape[0])[:, None]
            embed = ee.at[rows, target].set(de)[:, :t]

        # 5. emit: updated this frame AND (probation passed OR warmup window)
        frame_count = state.frame_count + 1
        warmup = (frame_count <= cfg.min_hits)[..., None]
        emit = (pool.alive
                & (pool.time_since_update < 1)
                & ((pool.hit_streak >= cfg.min_hits) | warmup))

        out = SortOutput(boxes=bbox.z_to_xyxy(x[..., :4]),
                         uid=pool.uid, emit=emit,
                         matched_det=assoc.matched_det, cls=pool.cls)
        return SortState(x, p, pool, frame_count, embed), out

    def _check_cost_inputs(self, det_class, det_embed):
        cfg = self.config
        if cfg.num_classes > 1 and det_class is None:
            raise ValueError("num_classes > 1 needs det_class inputs")
        if cfg.cost.uses_embed and det_embed is None:
            raise ValueError(f"cost {cfg.cost} needs det_embed inputs "
                             f"(embed_dim={cfg.cost.embed_dim})")

    # -------------------------------------------------- lane-persistent step
    def lane_step(self, lane: LaneSortState, det_boxes: jnp.ndarray,
                  det_mask: jnp.ndarray,
                  frame_mode: str = "auto",
                  stream_active: Optional[jnp.ndarray] = None,
                  det_class: Optional[jnp.ndarray] = None,
                  det_embed: Optional[jnp.ndarray] = None,
                  ) -> tuple[LaneSortState, SortOutput]:
        """One frame entirely in the persistent lane layout.

        Predict -> IoU -> association (``config.assoc``, DESIGN.md §6) ->
        masked update run as a single fused dispatch
        (``repro.kernels.ops.frame_step``; with ``assoc="hungarian"`` the
        lane-batched JV solve stage feeds that dispatch); tracker
        lifecycle, births, and emit are lane-major integer bookkeeping.
        Only the per-frame *outputs* (boxes/uid/emit — 6 scalars per slot,
        not the 49-entry covariance) leave the lane layout.

        ``stream_active [S]`` bool (optional) is the ragged-stream mask
        (DESIGN.md §3): streams with ``active=False`` are exact no-ops —
        state, lifecycle, and ``frame_count`` are untouched and nothing is
        emitted — inside the same single dispatch, so lane membership can
        churn every frame without re-dispatch or recompilation.
        """
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref

        self._check_cost_inputs(det_class, det_embed)
        cfg = self.config
        s = det_boxes.shape[0]
        t = cfg.max_trackers
        sp = lane.frame_count.shape[0]
        dt = lane.x.dtype
        x3 = lane.x.reshape(kalman.DIM_X, t, sp)
        p3 = lane.p.reshape(49, t, sp)
        det_l = jnp.pad(det_boxes.astype(dt),
                        ((0, sp - s), (0, 0), (0, 0))).transpose(1, 2, 0)
        dm_l = jnp.pad(det_mask, ((0, sp - s), (0, 0))).T        # [D, Sp]
        alive = lane.pool.alive                                  # [T, Sp]
        act = (None if stream_active is None
               else jnp.pad(stream_active, ((0, sp - s),)))      # [Sp] bool

        # pluggable-cost lane operands (DESIGN.md §10) — only materialized
        # for the kernel when the spec needs them, so the default config's
        # dispatch stays byte-identical
        dc_l = (None if det_class is None
                else jnp.pad(det_class.astype(jnp.int32),
                             ((0, sp - s), (0, 0))).T)           # [D, Sp]
        de_l = (None if det_embed is None
                else jnp.pad(det_embed.astype(dt),
                             ((0, sp - s), (0, 0), (0, 0))
                             ).transpose(1, 2, 0))               # [D, E, Sp]
        cost_kw = dict(cost=cfg.cost, num_classes=cfg.num_classes)
        if cfg.num_classes > 1:
            cost_kw.update(det_class=dc_l, trk_cls=lane.pool.cls)
        if cfg.cost.uses_embed:
            e = lane.embed.shape[0]
            cost_kw.update(det_embed=de_l,
                           trk_embed=lane.embed.reshape(e, t, sp))

        # 1-3. fused predict + IoU + assign + masked update (one dispatch;
        # the Hungarian mode's JV solve is a jitted stage feeding it)
        x3, p3, trk_to_det, matched_det = kops.frame_step(
            x3, p3, det_l, dm_l.astype(dt), alive.astype(dt),
            None if act is None else act.astype(dt)[None],
            iou_threshold=cfg.iou_threshold, block_s=self._block_s,
            mode=frame_mode, assoc=cfg.assoc, **cost_kw)

        # 4a. age & kill (elementwise — runs lane-major as-is)
        pool = slots.tick(lane.pool, trk_to_det >= 0, cfg.max_age)

        # 4b. births from unmatched detections into free slots
        unmatched_det = dm_l & ~matched_det
        if act is not None:
            unmatched_det = unmatched_det & act[None]
        slot_for = slots.assign_slots_lane(~pool.alive, unmatched_det)
        pool = slots.birth_lane(pool, slot_for, det_class=dc_l)
        z_det = kref.xyxy_to_z_lane(det_l)                       # [4, D, Sp]
        born = jnp.zeros((t, sp), bool)
        zb = jnp.zeros((4, t, sp), dt)
        slot_iota = jnp.arange(t, dtype=jnp.int32)[:, None]
        for di in range(slot_for.shape[0]):                      # D unrolled
            sel = slot_for[di][None, :] == slot_iota             # [T, Sp]
            born = born | sel
            zb = jnp.where(sel[None], z_det[:, di][:, None], zb)
        x_init = jnp.concatenate([zb, jnp.zeros((3, t, sp), dt)], axis=0)
        p_init = kalman.initial_covariance(dt).reshape(49)
        x3 = jnp.where(born[None], x_init, x3)
        p3 = jnp.where(born[None], p_init[:, None, None], p3)

        # 4c. appearance embeddings — the exact unrolled replace discipline
        # of ref.step_chunk_lane (matched rounds then birth rounds), so the
        # per-frame and chunk paths update embeds bit-identically
        embed_flat = lane.embed
        if cfg.cost.uses_embed:
            emb = lane.embed.reshape(e, t, sp)
            for di in range(de_l.shape[0]):                  # matched tracks
                m_sel = (trk_to_det == di)[None]
                emb = jnp.where(m_sel, de_l[di][:, None], emb)
            for di in range(slot_for.shape[0]):              # born tracks
                b_sel = (slot_for[di][None, :] == slot_iota)[None]
                emb = jnp.where(b_sel, de_l[di][:, None], emb)
            embed_flat = emb.reshape(e, t * sp)

        if act is not None:
            # inactive lanes: lifecycle freezes (the kernel already left
            # x/p untouched, and no matches/births happened above)
            pool = _select_pool(act[None], act, pool, lane.pool)
            frame_count = lane.frame_count + act.astype(jnp.int32)
        else:
            frame_count = lane.frame_count + 1

        # 5. emit: updated this frame AND (probation passed OR warmup)
        warmup = (frame_count <= cfg.min_hits)[None]             # [1, Sp]
        emit = (pool.alive
                & (pool.time_since_update < 1)
                & ((pool.hit_streak >= cfg.min_hits) | warmup))
        if act is not None:
            emit = emit & act[None]

        boxes_l = kref.z_to_xyxy_lane(x3[:4])                    # [T, 4, Sp]
        out = SortOutput(boxes=boxes_l[..., :s].transpose(2, 0, 1),
                         uid=pool.uid[:, :s].T, emit=emit[:, :s].T,
                         matched_det=matched_det[:, :s].T,
                         cls=pool.cls[:, :s].T)
        lane = LaneSortState(x3.reshape(kalman.DIM_X, t * sp),
                             p3.reshape(49, t * sp), pool, frame_count,
                             embed_flat)
        return lane, out

    def resize_ragged(self, state, num_lanes: int, new_num_lanes: int):
        """Migrate a ragged serving state between lane budgets (DESIGN.md
        §8).  ``num_lanes`` is the state's current budget (the fused
        :class:`LaneSortState` cannot tell its real lane count from its
        padded one, so the caller supplies it); ``new_num_lanes`` the
        target.  Grow re-initialises the appended lanes via the masked
        re-init; shrink drops the tail — the caller (the scheduler's
        shrink-by-drain protocol) guarantees those lanes are vacant.

        Both layouts migrate through the engine layout using the exact
        :func:`sort_state_of` / :func:`lane_state_of` inverses, so kept
        lanes — including lanes mid-sequence — are bit-identical before
        and after.  Runs outside the jitted chunk scan: a migration is a
        rare host-boundary event, never a per-step cost.
        """
        if self.config.use_kernels:
            eng_state = sort_state_of(state, num_lanes)
            return lane_state_of(resize_streams(eng_state, new_num_lanes),
                                 self._block_s)
        return resize_streams(state, new_num_lanes)

    # ------------------------------------------------------ ragged stepping
    def init_ragged(self, num_lanes: int):
        """Initial state for :meth:`step_ragged` — the scheduler's fixed
        lane budget.  Lane-persistent layout when ``use_kernels`` else the
        engine layout (both paths serve the ragged scheduler identically).
        """
        state = self.init(num_lanes)
        if self.config.use_kernels:
            return lane_state_of(state, self._block_s)
        return state

    def step_ragged(self, state, det_boxes: jnp.ndarray,
                    det_mask: jnp.ndarray, active: jnp.ndarray,
                    frame_mode: str = "auto",
                    det_class: Optional[jnp.ndarray] = None,
                    det_embed: Optional[jnp.ndarray] = None):
        """One frame for a ragged multiplex of sequences over fixed lanes.

        ``det_boxes [L, D, 4]``, ``det_mask [L, D]``, ``active [L]`` bool:
        lanes whose sequence has ended (or that are awaiting admission)
        pass ``active=False`` and are **exact no-ops** — state, lifecycle,
        and ``frame_count`` are untouched and ``emit`` is all-False — so a
        lane's track stream is bit-identical to running its sequences
        back-to-back alone, regardless of what the other lanes carry.

        ``state`` is whatever :meth:`init_ragged` returned for this engine
        (``LaneSortState`` on the fused path, masked within the single
        dispatch; ``SortState`` on the per-phase path, masked around
        :meth:`step`).  ``frame_mode`` forces the fused path's kernel
        backend (``kernels.ops.frame_step``'s ``mode``); the per-phase
        path has no kernel to force and ignores it.
        """
        if self.config.use_kernels:
            return self.lane_step(state, det_boxes, det_mask,
                                  frame_mode=frame_mode,
                                  stream_active=active,
                                  det_class=det_class,
                                  det_embed=det_embed)

        a1 = active[:, None]                                     # [L, 1]
        new, out = self.step(state, det_boxes, det_mask & a1,
                             det_class=det_class, det_embed=det_embed)
        pool = _select_pool(a1, active, new.pool, state.pool)
        masked = SortState(
            x=jnp.where(a1[..., None], new.x, state.x),
            p=jnp.where(a1[..., None, None], new.p, state.p),
            pool=pool,
            frame_count=jnp.where(active, new.frame_count,
                                  state.frame_count),
            embed=jnp.where(a1[..., None], new.embed, state.embed))
        out = out._replace(emit=out.emit & a1,
                           matched_det=out.matched_det & a1)
        return masked, out

    # ------------------------------------------------------ chunked stepping
    def run_chunk_ragged(self, state, det_boxes: jnp.ndarray,
                         det_mask: jnp.ndarray, active: jnp.ndarray,
                         reset: jnp.ndarray, mode: str = "auto",
                         det_class: Optional[jnp.ndarray] = None,
                         det_embed: Optional[jnp.ndarray] = None):
        """One planned serving chunk — ``F`` ragged steps — in a single
        call: the scheduler's dispatch unit (DESIGN.md §3/§9).

        ``det_boxes [F, L, D, 4]``, ``det_mask [F, L, D]``, ``active
        [F, L]`` bool, ``reset [F, L]`` bool — the host-planned admission
        schedule; ``reset[f, l]`` recycles lane ``l`` in the same step
        that carries the admitted sequence's first frame.  Returns
        ``(state, SortOutput stacked over F)``.

        Semantics are exactly ``F`` iterations of :func:`reset_ragged` +
        :meth:`step_ragged`.  With ``config.chunk_kernel=False`` (either
        engine path) that is literally what runs, as one ``lax.scan`` —
        ``F`` kernel dispatches per chunk on the fused path.  With
        ``config.chunk_kernel=True`` the whole loop moves inside ONE
        ``pallas_call`` (``kernels.chunk.fused_chunk``): the frame axis
        becomes the minor grid dimension, lane state stays VMEM-resident
        across the chunk, and dispatches per chunk drop from ``F`` to 1
        (``benchmarks/dispatch_overhead.py``) — bit-identical outputs
        either way (``tests/test_oracle_parity.py``).  ``mode`` forces
        the kernel backend as in ``kernels.ops.chunk_step``.
        """
        cfg = self.config
        if not cfg.chunk_kernel:
            present = [a is not None for a in (det_class, det_embed)]

            def body(st, inp):
                d, m, a, r = inp[:4]
                it = iter(inp[4:])
                dc, de = (next(it) if has else None for has in present)
                # recycle + admitted sequence's first frame: same step
                st = reset_ragged(st, r)
                return self.step_ragged(st, d, m, a, frame_mode=mode,
                                        det_class=dc, det_embed=de)

            xs = (det_boxes, det_mask, active, reset) + tuple(
                a for a in (det_class, det_embed) if a is not None)
            return jax.lax.scan(body, state, xs)

        from repro.kernels import ops as kops

        self._check_cost_inputs(det_class, det_embed)
        l = active.shape[1]
        t = cfg.max_trackers
        sp = state.frame_count.shape[0]
        dt = state.x.dtype
        grow = sp - l
        det_l = jnp.pad(det_boxes.astype(dt),
                        ((0, 0), (0, grow), (0, 0), (0, 0))
                        ).transpose(0, 2, 3, 1)               # [F, D, 4, Sp]
        dm_l = jnp.pad(det_mask, ((0, 0), (0, grow), (0, 0))
                       ).astype(dt).transpose(0, 2, 1)        # [F, D, Sp]
        act_l = jnp.pad(active, ((0, 0), (0, grow))
                        ).astype(dt)[:, None, :]              # [F, 1, Sp]
        rst_l = jnp.pad(reset, ((0, 0), (0, grow))
                        ).astype(jnp.int32)[:, None, :]       # [F, 1, Sp]
        dc_l = (None if det_class is None
                else jnp.pad(det_class.astype(jnp.int32),
                             ((0, 0), (0, grow), (0, 0))
                             ).transpose(0, 2, 1))            # [F, D, Sp]
        de_l = (None if det_embed is None
                else jnp.pad(det_embed.astype(dt),
                             ((0, 0), (0, grow), (0, 0), (0, 0))
                             ).transpose(0, 2, 3, 1))         # [F, D, E, Sp]
        cs, outs = kops.chunk_step(
            chunk_state_of(state), det_l, dm_l, act_l, rst_l,
            det_class=dc_l, det_embed=de_l,
            iou_threshold=cfg.iou_threshold, max_age=cfg.max_age,
            min_hits=cfg.min_hits, block_s=self._block_s, mode=mode,
            assoc=cfg.assoc, cost=cfg.cost, num_classes=cfg.num_classes)
        out = SortOutput(
            boxes=outs.boxes[..., :l].transpose(0, 3, 1, 2),  # [F, L, T, 4]
            uid=outs.uid[..., :l].transpose(0, 2, 1),
            emit=outs.emit[..., :l].transpose(0, 2, 1),
            matched_det=outs.matched_det[..., :l].transpose(0, 2, 1),
            cls=outs.cls[..., :l].transpose(0, 2, 1))
        return lane_state_of_chunk(cs), out

    # -------------------------------------------------------------------- run
    def run(self, state: SortState, frames: jnp.ndarray,
            frame_masks: jnp.ndarray,
            det_class: Optional[jnp.ndarray] = None,
            det_embed: Optional[jnp.ndarray] = None,
            ) -> tuple[SortState, SortOutput]:
        """Scan over the frame axis.

        ``frames [F, S, D, 4]``, ``frame_masks [F, S, D]`` ->
        outputs stacked over ``F``.  ``det_class [F, S, D] int32`` /
        ``det_embed [F, S, D, E]`` (optional) feed the pluggable
        association cost per frame (DESIGN.md §10).

        With ``use_kernels=True`` the state is converted to the persistent
        lane layout **once**, stays resident across the whole scan, and is
        converted back only here at the API boundary.
        """
        present = [a is not None for a in (det_class, det_embed)]
        xs = (frames, frame_masks) + tuple(
            a for a in (det_class, det_embed) if a is not None)

        if self.config.use_kernels:
            num_streams = frames.shape[1]

            def lane_body(lst, inp):
                boxes, mask = inp[:2]
                it = iter(inp[2:])
                dc, de = (next(it) if has else None for has in present)
                return self.lane_step(lst, boxes, mask,
                                      det_class=dc, det_embed=de)

            lane0 = lane_state_of(state, self._block_s)
            lane_f, outs = jax.lax.scan(lane_body, lane0, xs)
            return sort_state_of(lane_f, num_streams), outs

        def body(st, inp):
            boxes, mask = inp[:2]
            it = iter(inp[2:])
            dc, de = (next(it) if has else None for has in present)
            st, out = self.step(st, boxes, mask,
                                det_class=dc, det_embed=de)
            return st, out

        return jax.lax.scan(body, state, xs)


def _scatter_births(x, p, z_det, slot_for, dtype):
    """Write ``init_state(z)`` of each claimed detection into its slot."""
    s, t = x.shape[0], x.shape[1]
    d = slot_for.shape[-1]
    x0, p0 = kalman.init_state(z_det, dtype)                 # [S, D, 7], [S, D, 7, 7]
    claimed = slot_for >= 0
    # Claimed targets are distinct (assign_slots is a rank matching); all
    # unclaimed detections write the overflow slot ``t`` which is sliced off.
    target = jnp.where(claimed, slot_for, t)
    xe = jnp.concatenate([x, x[:, :1]], axis=1)              # [S, T+1, 7]
    pe = jnp.concatenate([p, p[:, :1]], axis=1)
    rows = jnp.arange(s)[:, None]
    xe = xe.at[rows, target].set(x0)
    pe = pe.at[rows, target].set(p0)
    return xe[:, :t], pe[:, :t]
