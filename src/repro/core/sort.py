"""SORT — Simple Online and Real-time Tracking, batched over streams.

Implements paper Algorithm 1 / Fig. 2's ``Update`` function as a single
jit-compiled, static-shape step over a *batch* of independent video streams:
the TPU realization of the paper's throughput-scaling result (one OpenMP
worker per stream -> one vector lane per stream; see DESIGN.md §2).

Per frame (paper Fig. 2):
  1. Kalman-predict every live tracker          (§ "Predict",   AI 2.4)
  2. IoU cost + Hungarian assignment + gating   (§ "Assign",    AI 1.5)
  3. Kalman-update matched trackers             (§ "Update",    AI 18)
  4. age/kill unmatched trackers, birth new trackers from unmatched
     detections                                 (§ "Create new")
  5. emit confirmed tracks                      (§ "Prepare output")

Lifecycle constants follow Bewley's reference implementation
(max_age=1, min_hits=3, iou_threshold=0.3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import association, bbox, kalman, slots


@dataclasses.dataclass(frozen=True)
class SortConfig:
    max_trackers: int = 16     # slot capacity T (>= max objects/frame; Table I max is 13)
    max_detections: int = 16   # padded detections per frame D
    iou_threshold: float = 0.3
    max_age: int = 1
    min_hits: int = 3
    dtype: str = "float32"
    # kernel injection (None -> pure-jnp reference path). Set by repro.kernels.ops.
    use_kernels: bool = False


class SortState(NamedTuple):
    x: jnp.ndarray        # [S, T, 7]  Kalman means
    p: jnp.ndarray        # [S, T, 7, 7] covariances
    pool: slots.SlotPool  # [S, T] lifecycle
    frame_count: jnp.ndarray  # [S] int32


class SortOutput(NamedTuple):
    boxes: jnp.ndarray    # [S, T, 4] xyxy of every slot (post update/birth)
    uid: jnp.ndarray      # [S, T] track id, -1 if dead
    emit: jnp.ndarray     # [S, T] bool — confirmed tracks to report this frame
    matched_det: jnp.ndarray  # [S, D] bool (for metrics)


class SortEngine:
    """Batched SORT over ``S`` independent streams.

    ``predict_fn(x, p) -> (x, p)`` / ``update_fn(x, p, z, mask) -> (x, p)`` /
    ``iou_fn(a, b) -> iou`` are injection points for Pallas kernels
    (``repro.kernels.ops``); defaults are the pure-jnp reference path so the
    engine runs identically on CPU.
    """

    def __init__(self, config: SortConfig,
                 predict_fn: Optional[Callable] = None,
                 update_fn: Optional[Callable] = None,
                 iou_fn: Optional[Callable] = None,
                 assoc_fn: Optional[Callable] = None):
        self.config = config
        self.params = kalman.KalmanParams.default(jnp.dtype(config.dtype))
        self._predict = predict_fn or (lambda x, p: kalman.predict(x, p, self.params))
        self._update = update_fn or (
            lambda x, p, z, m: kalman.masked_update(x, p, z, m, self.params))
        self._iou = iou_fn or bbox.iou_matrix
        self._assoc = assoc_fn or association.associate

    # ------------------------------------------------------------------ state
    def init(self, num_streams: int) -> SortState:
        cfg = self.config
        dt = jnp.dtype(cfg.dtype)
        return SortState(
            x=jnp.zeros((num_streams, cfg.max_trackers, kalman.DIM_X), dt),
            p=jnp.broadcast_to(kalman.initial_covariance(dt),
                               (num_streams, cfg.max_trackers,
                                kalman.DIM_X, kalman.DIM_X)).copy(),
            pool=slots.init_pool((num_streams,), cfg.max_trackers),
            frame_count=jnp.zeros((num_streams,), jnp.int32),
        )

    # ------------------------------------------------------------------- step
    def step(self, state: SortState, det_boxes: jnp.ndarray,
             det_mask: jnp.ndarray) -> tuple[SortState, SortOutput]:
        """One frame for every stream.

        ``det_boxes [S, D, 4]`` xyxy, ``det_mask [S, D]``.
        """
        cfg = self.config
        x, p, pool = state.x, state.p, state.pool

        # 1. predict (all slots; dead slots are ignored downstream)
        x, p = self._predict(x, p)
        trk_boxes = bbox.z_to_xyxy(x[..., :4])

        # 2. associate (Hungarian by default; injectable, e.g. greedy)
        assoc = self._assoc(det_boxes, det_mask, trk_boxes,
                            pool.alive, cfg.iou_threshold,
                            iou_fn=self._iou)

        # 3. update matched trackers with their detection's observation
        safe_det = jnp.where(assoc.trk_to_det >= 0, assoc.trk_to_det, 0)
        z_all = bbox.xyxy_to_z(det_boxes)                     # [S, D, 4]
        z_trk = jnp.take_along_axis(z_all, safe_det[..., None], axis=-2)
        x, p = self._update(x, p, z_trk.astype(x.dtype), assoc.matched_trk)

        # 4a. age & kill
        pool = slots.tick(pool, assoc.matched_trk, cfg.max_age)

        # 4b. births from unmatched detections into free slots
        slot_for = slots.assign_slots(~pool.alive, assoc.unmatched_det)
        pool = slots.birth(pool, slot_for)
        z_det = z_all.astype(x.dtype)
        x, p = _scatter_births(x, p, z_det, slot_for, jnp.dtype(cfg.dtype))

        # 5. emit: updated this frame AND (probation passed OR warmup window)
        frame_count = state.frame_count + 1
        warmup = (frame_count <= cfg.min_hits)[..., None]
        emit = (pool.alive
                & (pool.time_since_update < 1)
                & ((pool.hit_streak >= cfg.min_hits) | warmup))

        out = SortOutput(boxes=bbox.z_to_xyxy(x[..., :4]),
                         uid=pool.uid, emit=emit,
                         matched_det=assoc.matched_det)
        return SortState(x, p, pool, frame_count), out

    # -------------------------------------------------------------------- run
    def run(self, state: SortState, frames: jnp.ndarray,
            frame_masks: jnp.ndarray) -> tuple[SortState, SortOutput]:
        """Scan over the frame axis.

        ``frames [F, S, D, 4]``, ``frame_masks [F, S, D]`` ->
        outputs stacked over ``F``.
        """
        def body(st, inp):
            boxes, mask = inp
            st, out = self.step(st, boxes, mask)
            return st, out

        return jax.lax.scan(body, state, (frames, frame_masks))


def _scatter_births(x, p, z_det, slot_for, dtype):
    """Write ``init_state(z)`` of each claimed detection into its slot."""
    s, t = x.shape[0], x.shape[1]
    d = slot_for.shape[-1]
    x0, p0 = kalman.init_state(z_det, dtype)                 # [S, D, 7], [S, D, 7, 7]
    claimed = slot_for >= 0
    # Claimed targets are distinct (assign_slots is a rank matching); all
    # unclaimed detections write the overflow slot ``t`` which is sliced off.
    target = jnp.where(claimed, slot_for, t)
    xe = jnp.concatenate([x, x[:, :1]], axis=1)              # [S, T+1, 7]
    pe = jnp.concatenate([p, p[:, :1]], axis=1)
    rows = jnp.arange(s)[:, None]
    xe = xe.at[rows, target].set(x0)
    pe = pe.at[rows, target].set(p0)
    return xe[:, :t], pe[:, :t]
