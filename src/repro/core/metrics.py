"""Tracking quality metrics (MOTA-style) for validating the engine.

Used by tests and ``benchmarks/datasets.py`` to confirm the batched engine
tracks as well as the reference — the paper validates by matching the
original code's output; we do the same plus aggregate metrics.
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linear_sum_assignment


def frame_matches(gt_boxes, gt_mask, out_boxes, out_mask, iou_thr=0.5):
    """Match GT to tracker output in one frame; returns (tp, fp, fn, pairs)."""
    g = np.where(gt_mask)[0]
    o = np.where(out_mask)[0]
    if len(g) == 0 or len(o) == 0:
        return 0, len(o), len(g), []
    iou = _iou_mat(gt_boxes[g], out_boxes[o])
    ri, ci = linear_sum_assignment(-iou)
    pairs = [(g[i], o[j]) for i, j in zip(ri, ci) if iou[i, j] >= iou_thr]
    tp = len(pairs)
    return tp, len(o) - tp, len(g) - tp, pairs


def mota(gt_boxes, gt_mask, out_boxes, out_uids, out_emit, iou_thr=0.5):
    """Multi-Object Tracking Accuracy + id switches over one sequence.

    ``gt_boxes [F, K, 4]``, ``gt_mask [F, K]``; tracker outputs
    ``out_boxes [F, T, 4]``, ``out_uids [F, T]``, ``out_emit [F, T]``.
    """
    f = gt_boxes.shape[0]
    tp = fp = fn = idsw = 0
    last_uid = {}  # gt index -> last matched tracker uid
    for t in range(f):
        tpi, fpi, fni, pairs = frame_matches(
            gt_boxes[t], gt_mask[t], out_boxes[t], out_emit[t], iou_thr)
        tp, fp, fn = tp + tpi, fp + fpi, fn + fni
        for gi, oi in pairs:
            uid = int(out_uids[t, oi])
            if gi in last_uid and last_uid[gi] != uid:
                idsw += 1
            last_uid[gi] = uid
    n_gt = int(gt_mask.sum())
    value = 1.0 - (fn + fp + idsw) / max(n_gt, 1)
    return {"mota": value, "tp": tp, "fp": fp, "fn": fn,
            "id_switches": idsw, "num_gt": n_gt}


def _iou_mat(a, b):
    ix1 = np.maximum(a[:, None, 0], b[None, :, 0])
    iy1 = np.maximum(a[:, None, 1], b[None, :, 1])
    ix2 = np.minimum(a[:, None, 2], b[None, :, 2])
    iy2 = np.minimum(a[:, None, 3], b[None, :, 3])
    inter = np.maximum(ix2 - ix1, 0) * np.maximum(iy2 - iy1, 0)
    aa = np.maximum(a[:, 2] - a[:, 0], 0) * np.maximum(a[:, 3] - a[:, 1], 0)
    ab = np.maximum(b[:, 2] - b[:, 0], 0) * np.maximum(b[:, 3] - b[:, 1], 0)
    return inter / np.maximum(aa[:, None] + ab[None, :] - inter, 1e-9)
