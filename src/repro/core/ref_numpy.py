"""Reference SORT — faithful per-stream numpy/scipy port of Bewley et al.

This mirrors the *original Python* implementation the paper profiles
(object-oriented, one KalmanBoxTracker per object, per-op numpy dispatch,
scipy Hungarian).  It serves two purposes:

1. **Oracle** for the batched JAX engine (``tests/test_sort.py`` checks the
   two produce identical track IDs/boxes on synthetic data).
2. **Baseline** for ``benchmarks/speedup.py`` — the analogue of the paper's
   Table V (their C rewrite vs. the original Python; here: fused jitted
   batch vs. per-op interpreted loop).
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linear_sum_assignment


def xyxy_to_z(box):
    w = box[2] - box[0]
    h = box[3] - box[1]
    return np.array([box[0] + w / 2.0, box[1] + h / 2.0, w * h, w / max(h, 1e-9)])


def z_to_xyxy(x):
    s = max(x[2], 0.0)
    r = max(x[3], 1e-9)
    w = np.sqrt(s * r)
    h = s / max(w, 1e-9)
    return np.array([x[0] - w / 2, x[1] - h / 2, x[0] + w / 2, x[1] + h / 2])


def iou(a, b):
    ix1, iy1 = max(a[0], b[0]), max(a[1], b[1])
    ix2, iy2 = min(a[2], b[2]), min(a[3], b[3])
    iw, ih = max(ix2 - ix1, 0.0), max(iy2 - iy1, 0.0)
    inter = iw * ih
    ua = max(a[2] - a[0], 0) * max(a[3] - a[1], 0)
    ub = max(b[2] - b[0], 0) * max(b[3] - b[1], 0)
    return inter / max(ua + ub - inter, 1e-9)


class KalmanBoxTracker:
    """One tracker, constant-velocity model — filterpy-equivalent numpy.

    ``cls`` is the track's object class (frozen at birth; DESIGN.md §10)
    and ``embed`` its appearance embedding, replaced by each matched
    detection's — mirroring the engine's per-track class/embed state.
    """

    def __init__(self, box, uid, cls=0, embed=None):
        dim_x, dim_z = 7, 4
        self.F = np.eye(dim_x)
        self.F[0, 4] = self.F[1, 5] = self.F[2, 6] = 1.0
        self.H = np.zeros((dim_z, dim_x))
        self.H[np.arange(4), np.arange(4)] = 1.0
        self.R = np.diag([1.0, 1.0, 10.0, 10.0])
        self.Q = np.diag([1.0, 1.0, 1.0, 1.0, 0.01, 0.01, 1e-4])
        self.P = np.diag([10.0, 10, 10, 10, 1e4, 1e4, 1e4])
        self.x = np.zeros(dim_x)
        self.x[:4] = xyxy_to_z(box)
        self.uid = uid
        self.cls = cls
        self.embed = embed
        self.time_since_update = 0
        self.hits = 0
        self.hit_streak = 0
        self.age = 0

    def predict(self):
        if self.x[2] + self.x[6] <= 0:
            self.x[6] = 0.0
        self.x = self.F @ self.x
        self.P = self.F @ self.P @ self.F.T + self.Q
        self.age += 1
        if self.time_since_update > 0:
            self.hit_streak = 0
        self.time_since_update += 1
        return z_to_xyxy(self.x)

    def update(self, box, embed=None):
        self.time_since_update = 0
        self.hits += 1
        self.hit_streak += 1
        if embed is not None:
            self.embed = embed
        z = xyxy_to_z(box)
        y = z - self.H @ self.x
        s = self.H @ self.P @ self.H.T + self.R
        k = self.P @ self.H.T @ np.linalg.inv(s)
        self.x = self.x + k @ y
        self.P = (np.eye(7) - k @ self.H) @ self.P

    def maha_d2(self, box):
        """Squared Mahalanobis distance of ``box``'s observation from the
        *post-predict* observation distribution (innovation covariance
        ``S = P'₄ₓ₄ + R`` — call after :meth:`predict`)."""
        y = xyxy_to_z(box) - self.x[:4]
        s = self.P[:4, :4] + self.R
        return float(y @ np.linalg.inv(s) @ y)


class Sort:
    """Per-stream SORT, Bewley-reference semantics.

    ``assoc`` selects the association oracle, mirroring
    ``SortConfig.assoc`` (DESIGN.md §6): ``"hungarian"`` (Bewley's optimal
    assignment via scipy — the default on both engine paths, including
    the fused lane path's JV stage) or ``"greedy"`` (global best-first
    with the same det-major tie-breaking as ``core.greedy.greedy_assign``),
    so every path x algorithm combination has an end-to-end numpy ground
    truth (``tests/test_oracle_parity.py``).
    """

    def __init__(self, max_age=1, min_hits=3, iou_threshold=0.3,
                 assoc="hungarian", cost=None, num_classes=1):
        from . import cost as cost_mod  # numpy-safe: no jax at module level

        if assoc not in ("hungarian", "greedy"):
            raise ValueError(f"unknown assoc {assoc!r}")
        self.max_age = max_age
        self.min_hits = min_hits
        self.iou_threshold = iou_threshold
        self.assoc = assoc
        self.cost = cost_mod.IOU if cost is None else cost
        self.num_classes = num_classes
        self.trackers: list[KalmanBoxTracker] = []
        self.frame_count = 0
        self.next_uid = 1

    def update(self, dets: np.ndarray, classes=None, embeds=None):
        """``dets [D, 4]`` xyxy -> list of ``(x1, y1, x2, y2, uid, cls)``.

        ``classes [D]`` int / ``embeds [D, E]`` (optional) feed the
        composed cost, mirroring ``SortEngine.step``'s ``det_class`` /
        ``det_embed`` operands.
        """
        self.frame_count += 1
        preds = [t.predict() for t in self.trackers]

        # associate
        matches, unmatched_dets, unmatched_trks = self._associate(
            dets, preds, classes, embeds)
        for d, t in matches:
            self.trackers[t].update(
                dets[d], None if embeds is None else embeds[d])
        for d in unmatched_dets:
            self.trackers.append(KalmanBoxTracker(
                dets[d], self.next_uid,
                cls=0 if classes is None else int(classes[d]),
                embed=None if embeds is None else embeds[d]))
            self.next_uid += 1

        out = []
        kept = []
        for t in self.trackers:
            if t.time_since_update < 1 and (
                    t.hit_streak >= self.min_hits
                    or self.frame_count <= self.min_hits):
                out.append(np.concatenate([z_to_xyxy(t.x), [t.uid, t.cls]]))
            if t.time_since_update <= self.max_age:
                kept.append(t)
        self.trackers = kept
        return out

    def _score_and_feasible(self, dets, mat, classes, embeds):
        """Composed score + hard pair feasibility (class partition ∧
        Mahalanobis gate) — the numpy mirror of
        ``core.cost.score_and_feasible_batch`` over live trackers."""
        nd, nt = mat.shape
        cost = self.cost
        score = cost.iou_weight * mat
        if cost.uses_embed:
            for i in range(nd):
                for j in range(nt):
                    score[i, j] += cost.embed_weight * float(
                        np.dot(embeds[i], self.trackers[j].embed))
        feasible = np.ones((nd, nt), bool)
        if self.num_classes > 1:
            for i in range(nd):
                for j in range(nt):
                    feasible[i, j] &= (int(classes[i])
                                       == self.trackers[j].cls)
        if cost.uses_maha:
            for i in range(nd):
                for j in range(nt):
                    feasible[i, j] &= (self.trackers[j].maha_d2(dets[i])
                                       <= cost.maha_gate)
        return score, feasible

    def _associate(self, dets, preds, classes=None, embeds=None):
        nd, nt = len(dets), len(preds)
        if nd == 0 or nt == 0:
            return [], list(range(nd)), list(range(nt))
        mat = np.zeros((nd, nt))
        for i in range(nd):
            for j in range(nt):
                mat[i, j] = iou(dets[i], preds[j])
        plain = self.cost.is_iou_only and self.num_classes == 1
        if not plain:
            score, feasible = self._score_and_feasible(
                dets, mat, classes, embeds)
        matches, md, mt = [], set(), set()
        if self.assoc == "greedy" and plain:
            # global best-first; flat row-major argmax = det-major
            # tie-breaking, mirroring core.greedy.greedy_assign
            score = np.where(mat >= self.iou_threshold, mat, -1.0)
            for _ in range(min(nd, nt)):
                i, j = divmod(int(np.argmax(score)), nt)
                if score[i, j] <= 0.0:
                    break
                matches.append((i, j))
                md.add(i)
                mt.add(j)
                score[i, :] = -1.0
                score[:, j] = -1.0
        elif self.assoc == "greedy":
            # scored path: core.greedy's _NEG/_STOP sentinels so genuinely
            # negative composed scores stay matchable
            s = np.where((mat >= self.iou_threshold) & feasible,
                         score, -1.0e30)
            for _ in range(min(nd, nt)):
                i, j = divmod(int(np.argmax(s)), nt)
                if s[i, j] <= -1.0e29:
                    break
                matches.append((i, j))
                md.add(i)
                mt.add(j)
                s[i, :] = -1.0e30
                s[:, j] = -1.0e30
        elif plain:
            ri, ci = linear_sum_assignment(-mat)
            for i, j in zip(ri, ci):
                if mat[i, j] >= self.iou_threshold:
                    matches.append((i, j))
                    md.add(i)
                    mt.add(j)
        else:
            # mirror core.hungarian.pad_cost_matrix: embed the feasible
            # pairs in an n x n square whose pad is precision-safe yet
            # always loses to any real match (a fixed huge constant would
            # absorb the real score differences), so one solve equals the
            # per-class block-diagonal solves
            cost_m = -score
            vals = cost_m[feasible]
            cmax = max(float(vals.max()), 0.0) if vals.size else 0.0
            cmin = min(float(vals.min()), 0.0) if vals.size else 0.0
            n = max(nd, nt)
            pad = cmax + n * (cmax - cmin) + 1.0
            solve = np.full((n, n), pad)
            solve[:nd, :nt] = np.where(feasible, cost_m, pad)
            ri, ci = linear_sum_assignment(solve)
            for i, j in zip(ri, ci):
                if (i < nd and j < nt and feasible[i, j]
                        and mat[i, j] >= self.iou_threshold):
                    matches.append((i, j))
                    md.add(i)
                    mt.add(j)
        return (matches,
                [i for i in range(nd) if i not in md],
                [j for j in range(nt) if j not in mt])
