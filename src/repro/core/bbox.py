"""Bounding-box geometry for SORT.

SORT's observation vector is ``z = [u, v, s, r]`` where ``(u, v)`` is the box
center, ``s`` the area (scale) and ``r`` the aspect ratio (w/h, modeled as
constant).  Boxes on the wire are ``[x1, y1, x2, y2]``.

All functions are shape-polymorphic over leading batch axes and jit/vmap safe.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-9


def xyxy_to_z(box: jnp.ndarray) -> jnp.ndarray:
    """``[..., 4] (x1,y1,x2,y2) -> [..., 4] (u,v,s,r)``."""
    x1, y1, x2, y2 = box[..., 0], box[..., 1], box[..., 2], box[..., 3]
    w = x2 - x1
    h = y2 - y1
    u = x1 + w / 2.0
    v = y1 + h / 2.0
    s = w * h
    r = w / jnp.maximum(h, _EPS)
    return jnp.stack([u, v, s, r], axis=-1)


def z_to_xyxy(z: jnp.ndarray) -> jnp.ndarray:
    """``[..., >=4] (u,v,s,r,...) -> [..., 4] (x1,y1,x2,y2)``.

    Accepts the full 7-dim Kalman state as well (extra dims ignored).
    Negative predicted areas (possible transiently before SORT's scale-velocity
    clamp) are clamped to zero so the sqrt stays finite.
    """
    u, v, s, r = z[..., 0], z[..., 1], z[..., 2], z[..., 3]
    s = jnp.maximum(s, 0.0)
    r = jnp.maximum(r, _EPS)
    w = jnp.sqrt(s * r)
    h = s / jnp.maximum(w, _EPS)
    return jnp.stack([u - w / 2.0, v - h / 2.0, u + w / 2.0, v + h / 2.0], axis=-1)


def iou_matrix(boxes_a: jnp.ndarray, boxes_b: jnp.ndarray) -> jnp.ndarray:
    """Pairwise IoU.

    ``boxes_a: [..., A, 4]``, ``boxes_b: [..., B, 4]`` -> ``[..., A, B]``.
    Degenerate boxes produce IoU 0.
    """
    a = boxes_a[..., :, None, :]
    b = boxes_b[..., None, :, :]
    ix1 = jnp.maximum(a[..., 0], b[..., 0])
    iy1 = jnp.maximum(a[..., 1], b[..., 1])
    ix2 = jnp.minimum(a[..., 2], b[..., 2])
    iy2 = jnp.minimum(a[..., 3], b[..., 3])
    iw = jnp.maximum(ix2 - ix1, 0.0)
    ih = jnp.maximum(iy2 - iy1, 0.0)
    inter = iw * ih
    area_a = jnp.maximum(a[..., 2] - a[..., 0], 0.0) * jnp.maximum(a[..., 3] - a[..., 1], 0.0)
    area_b = jnp.maximum(b[..., 2] - b[..., 0], 0.0) * jnp.maximum(b[..., 3] - b[..., 1], 0.0)
    union = area_a + area_b - inter
    return inter / jnp.maximum(union, _EPS)
