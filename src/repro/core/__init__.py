"""Core SORT library — the paper's contribution as composable JAX modules.

Kalman filter (tiny-matrix batched), Hungarian assignment (lax), IoU
association, pluggable cost composition (``cost``, DESIGN.md §10),
slot-pool lifecycle, and the batched SortEngine.
"""
from . import (association, bbox, cost, greedy, hungarian,  # noqa: F401
               kalman, metrics, slots)
from .sort import (LaneSortState, SortConfig, SortEngine,  # noqa: F401
                   SortOutput, SortState, lane_state_of, reset_lanes,
                   reset_ragged, reset_streams, resize_streams,
                   sort_state_of)
