"""Greedy association — ablation baseline for the Hungarian solver.

Real-time trackers often replace the optimal assignment with greedy
best-first matching (O(n^2 log n), trivially vectorizable).  SORT's paper
uses the Hungarian method; this module quantifies what the optimal solver
buys (see ``benchmarks/association_ablation.py``): greedy is ~identical on
easy scenes and degrades under dense/ambiguous detections.

Batched, static-shape, jit/vmap-safe like :mod:`repro.core.hungarian`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Retirement sentinel / stop threshold for the general scored path: a
# composed score (weighted IoU + embedding term, ``core.cost``) can be
# legitimately negative, so the IoU path's ``-1.0 / > 0.0`` pair would
# misread real scores as exhausted.  Mirrored exactly by the numpy oracle
# (``core.ref_numpy``), so greedy decisions stay comparable bit for bit.
_NEG = -1.0e30
_STOP = -1.0e29


def greedy_assign(iou: jnp.ndarray, det_mask: jnp.ndarray,
                  trk_mask: jnp.ndarray, iou_threshold: float = 0.3,
                  score=None, feasible=None):
    """Best-first matching on an IoU matrix.

    ``iou [..., D, T]``; returns ``det_to_trk [..., D] int32`` (-1 =
    unmatched).  Iteratively takes the globally best remaining pair above
    the threshold — ``min(D, T)`` rounds of masked argmax.

    ``score [..., D, T]`` (optional) replaces IoU as the best-first
    objective (the IoU threshold still gates pair validity); ``feasible
    [..., D, T]`` (optional) hard-masks pairs (class partition /
    Mahalanobis gate, DESIGN.md §10).  With ``score=None`` the original
    ``-1.0``-sentinel path runs byte-identically; a provided score uses
    the ``_NEG`` sentinel so genuinely negative scores stay matchable.
    """
    d, t = iou.shape[-2], iou.shape[-1]
    batch = iou.shape[:-2]
    if d == 0 or t == 0:  # degenerate frame: argmax over a size-0 axis
        return jnp.full(batch + (d,), -1, jnp.int32)
    valid = (det_mask[..., :, None] & trk_mask[..., None, :]
             & (iou >= iou_threshold))
    if feasible is not None:
        valid = valid & feasible
    sentinel = -1.0 if score is None else _NEG
    stop = 0.0 if score is None else _STOP
    score = jnp.where(valid, iou if score is None else score, sentinel)
    n_rounds = min(d, t)

    def body(carry, _):
        score, out = carry
        flat = score.reshape(batch + (d * t,))
        idx = jnp.argmax(flat, axis=-1)
        best = jnp.take_along_axis(flat, idx[..., None], -1)[..., 0]
        di, ti = idx // t, idx % t
        ok = best > stop
        # record the match
        upd = jnp.where(ok, ti.astype(jnp.int32), -1)
        out = _set_at(out, jnp.where(ok, di, d), upd)          # overflow row d
        # retire the matched row and column
        row_dead = jnp.arange(d) == jnp.where(ok, di, -1)[..., None]
        col_dead = jnp.arange(t) == jnp.where(ok, ti, -1)[..., None]
        score = jnp.where(row_dead[..., None] | col_dead[..., None, :],
                          sentinel, score)
        return (score, out), None

    out0 = jnp.full(batch + (d,), -1, jnp.int32)
    (_, out), _ = lax.scan(body, (score, out0), None, length=n_rounds)
    return out


def greedy_assign_lane(iou: jnp.ndarray, det_mask: jnp.ndarray,
                       trk_mask: jnp.ndarray, iou_threshold: float = 0.3,
                       score=None, feasible=None):
    """Lane-layout port of :func:`greedy_assign` (DESIGN.md §2).

    Batch on the *trailing* axes so the per-round masked argmax runs once
    over the whole lane block: ``iou [D, T, ...]``, ``det_mask [D, ...]``,
    ``trk_mask [T, ...]`` (bool or 0/1 float).  Returns
    ``(trk_to_det [T, ...] int32, matched_det [D, ...] bool)`` — the
    inverted form the SORT update consumes, matching what
    :func:`greedy_assign` + scatter-inversion produce (same flat row-major
    ``d*T + t`` argmax order, so tie-breaking is identical).
    ``score`` / ``feasible`` (optional, ``[D, T, ...]``) carry the
    composed association cost with the same sentinel rules as
    :func:`greedy_assign`, so both layouts decide identically.

    The round loop is a trace-time-unrolled ``min(D, T)`` iterations of
    pure elementwise/reduce ops, so it is legal inside a Pallas kernel
    body (see ``repro.kernels.frame``).
    """
    d, t = iou.shape[0], iou.shape[1]
    lanes = iou.shape[2:]
    valid = ((det_mask[:, None] > 0) & (trk_mask[None, :] > 0)
             & (iou >= iou_threshold))
    if feasible is not None:
        valid = valid & feasible
    sentinel = -1.0 if score is None else _NEG
    stop = 0.0 if score is None else _STOP
    score = jnp.where(valid, iou if score is None else score, sentinel)
    trk_to_det = jnp.full((t,) + lanes, -1, jnp.int32)
    matched_det = jnp.zeros((d,) + lanes, bool)
    di_iota = jnp.arange(d, dtype=jnp.int32).reshape((d,) + (1,) * len(lanes))
    ti_iota = jnp.arange(t, dtype=jnp.int32).reshape((t,) + (1,) * len(lanes))

    for _ in range(min(d, t)):
        flat = score.reshape((d * t,) + lanes)
        idx = jnp.argmax(flat, axis=0).astype(jnp.int32)     # [...]
        best = jnp.max(flat, axis=0)
        ok = best > stop
        di, ti = idx // t, idx % t
        hit_trk = (ti_iota == ti[None]) & ok[None]           # [T, ...]
        hit_det = (di_iota == di[None]) & ok[None]           # [D, ...]
        trk_to_det = jnp.where(hit_trk, di[None], trk_to_det)
        matched_det = matched_det | hit_det
        score = jnp.where(hit_det[:, None] | hit_trk[None, :],
                          sentinel, score)
    return trk_to_det, matched_det


def _set_at(buf, idx, val):
    """Batched ``buf[..., idx] = val`` with an overflow slot."""
    d = buf.shape[-1]
    ext = jnp.concatenate([buf, jnp.full(buf.shape[:-1] + (1,), -1,
                                         buf.dtype)], -1)
    flat = ext.reshape(-1, d + 1)
    rows = jnp.arange(flat.shape[0])
    flat = flat.at[rows, idx.reshape(-1)].set(val.reshape(-1))
    return flat.reshape(ext.shape)[..., :d]


def greedy_iou_fn_for_engine(iou_threshold: float = 0.3):
    """Adapter producing an ``associate``-compatible replacement — the
    non-fused engine's association when ``SortConfig.assoc == "greedy"``
    (the fused path uses :func:`greedy_assign_lane` in-kernel instead,
    DESIGN.md §6)."""
    from . import association

    def associate_greedy(det_boxes, det_mask, trk_boxes, trk_mask,
                         thr=iou_threshold, iou_fn=None,
                         score=None, feasible=None):
        from . import bbox
        iou = (iou_fn or bbox.iou_matrix)(det_boxes, trk_boxes)
        return greedy_associate_from_iou(iou, det_mask, trk_mask, thr,
                                         score=score, feasible=feasible)

    return associate_greedy


def greedy_associate_from_iou(iou, det_mask, trk_mask,
                              iou_threshold: float = 0.3,
                              score=None, feasible=None):
    """Greedy twin of ``association.associate_from_iou``: best-first solve
    on a precomputed IoU matrix ``[..., D, T]``, inverted into the full
    :class:`~repro.core.association.Association` the engine consumes.
    ``score`` / ``feasible`` plug in the composed cost (``core.cost``)."""
    from . import association

    det_to_trk = greedy_assign(iou, det_mask, trk_mask, iou_threshold,
                               score=score, feasible=feasible)
    d, t = iou.shape[-2], iou.shape[-1]
    batch = iou.shape[:-2]
    good = det_to_trk >= 0
    safe = jnp.where(good, det_to_trk, 0)
    overflow = jnp.full(batch + (t + 1,), -1, jnp.int32)
    scatter_idx = jnp.where(good, safe, t)
    src = jnp.broadcast_to(jnp.arange(d), det_to_trk.shape) \
        .astype(jnp.int32)
    flat = overflow.reshape(-1, t + 1)
    rows = jnp.arange(flat.shape[0])[:, None]
    trk_to_det = flat.at[
        rows, scatter_idx.reshape(-1, d)].set(
        src.reshape(-1, d)).reshape(batch + (t + 1,))[..., :t]
    matched_trk = trk_to_det >= 0
    return association.Association(
        det_to_trk=jnp.where(good, safe, -1).astype(jnp.int32),
        trk_to_det=trk_to_det,
        matched_det=good, matched_trk=matched_trk,
        unmatched_det=det_mask & ~good,
        unmatched_trk=trk_mask & ~matched_trk,
        iou=iou)
