"""Quickstart: track objects across a batch of synthetic video streams.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine, metrics
from repro.data.synthetic import SceneConfig, generate_scene


def main():
    # 1. Make a synthetic 100-frame scene with ~8 objects (MOT15-shaped).
    scene_cfg = SceneConfig(num_frames=100, max_objects=8, seed=0)
    gt_boxes, gt_mask, det_boxes, det_mask = generate_scene(scene_cfg)
    print(f"frames={det_boxes.shape[0]}  det slots={det_boxes.shape[1]}")

    # 2. Build the batched SORT engine (paper defaults) for 4 parallel
    #    streams — we replicate the scene to show the throughput axis.
    engine = SortEngine(SortConfig(max_trackers=16,
                                   max_detections=det_boxes.shape[1]))
    streams = 4
    state = engine.init(streams)
    frames = jnp.asarray(np.repeat(det_boxes[:, None], streams, 1))
    masks = jnp.asarray(np.repeat(det_mask[:, None], streams, 1))

    # 3. One jitted call scans all frames for all streams.
    state, out = jax.jit(engine.run)(state, frames, masks)

    # 4. Inspect stream 0: emitted tracks per frame + tracking quality.
    for t in (0, 10, 50, 99):
        em = np.asarray(out.emit[t, 0])
        ids = np.asarray(out.uid[t, 0])[em]
        print(f"frame {t:3d}: {em.sum()} tracks, ids={sorted(ids.tolist())}")
    m = metrics.mota(gt_boxes, gt_mask, np.asarray(out.boxes[:, 0]),
                     np.asarray(out.uid[:, 0]), np.asarray(out.emit[:, 0]))
    print(f"MOTA={m['mota']:.3f}  id_switches={m['id_switches']} "
          f"(tp={m['tp']} fp={m['fp']} fn={m['fn']})")


if __name__ == "__main__":
    main()
