"""End-to-end tracking service — the paper's workload as a deployable driver.

Ingests MOT15-format detection files (or synthesizes Table-I-shaped ones)
and serves them through the online multi-stream scheduler
(``repro.serve.StreamScheduler``): ragged-length sequences are multiplexed
onto a fixed lane budget, lanes are recycled the moment a sequence ends
(masked re-init + next admission in the same fused step, DESIGN.md §3),
and results drain in submission order as MOT15 submission files.

    PYTHONPATH=src python examples/tracking_service.py --replicate 4 \
        --lanes 8 --out /tmp/sort_out

``--devices N`` shards the lane budget over an N-device ``("lanes",)``
mesh (DESIGN.md §7) — each device scans its own lane shard, bit-identical
to the single-device run.  On CPU, export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.

``--serve`` routes everything through the crash-exact service front-end
(``repro.serve.TrackingService``, DESIGN.md §11): results are written as
they finish, and with ``--ckpt-dir`` the full service state checkpoints
at every ``--ckpt-every``-th chunk boundary, so a SIGKILL'd run resumed
with ``--resume`` produces byte-identical output files::

    PYTHONPATH=src python examples/tracking_service.py --serve \
        --ckpt-dir /tmp/trk_ckpt --out /tmp/sort_out            # killed...
    PYTHONPATH=src python examples/tracking_service.py --serve \
        --ckpt-dir /tmp/trk_ckpt --out /tmp/sort_out --resume   # ...resumed

``--kill-after-chunks N`` SIGKILLs the process after N chunks (exit 137)
— the CI soak's deterministic crash injection.
"""
import argparse
import asyncio
import os
import signal
import time

import numpy as np

from repro.core import SortConfig, SortEngine, cost as cost_mod
from repro.data import mot
from repro.data.synthetic import (SceneConfig, generate_multiclass_scene,
                                  generate_scene)
from repro.serve import StreamScheduler
from repro.sharding import lane_mesh


def load_or_synthesize(det_dir, num_classes=1, embed_dim=0):
    """``[(name, det_boxes, det_mask, det_class|None, det_embed|None)]``.

    Multi-class / embed configs read the class column from real det files
    (clamped into ``[0, num_classes)``; MOT15 files carry ``-1`` = no
    class) and code up identity embeddings from it; synthetic sequences
    come from the multi-class generator directly.
    """
    multi = num_classes > 1 or embed_dim > 0
    seqs = []
    if det_dir and os.path.isdir(det_dir):
        for name in sorted(os.listdir(det_dir)):
            if not name.endswith(".txt"):
                continue
            db, dm, dc, _ = mot.read_det_file(
                os.path.join(det_dir, name), with_extras=True)
            dc = np.clip(dc, 0, max(num_classes - 1, 0)).astype(np.int32)
            de = None
            if embed_dim > 0:
                de = np.eye(embed_dim, dtype=np.float32)[dc % embed_dim]
            seqs.append((name[:-4], db, dm,
                         dc if num_classes > 1 else None, de))
    if not seqs:  # synthesize the 11 paper sequences
        for i, (name, (frames, max_obj)) in enumerate(mot.TABLE_I.items()):
            cfg = SceneConfig(num_frames=frames, max_objects=max_obj, seed=i)
            if multi:
                _, _, _, db, dm, dc, de = generate_multiclass_scene(
                    cfg, num_classes=max(num_classes, 1),
                    embed_dim=max(embed_dim, 1))
                seqs.append((name, db, dm,
                             dc if num_classes > 1 else None,
                             de if embed_dim > 0 else None))
            else:
                _, _, db, dm = generate_scene(cfg)
                seqs.append((name, db, dm, None, None))
    return seqs


async def _serve(sched, seqs, args) -> int:
    """The --serve path: pump the service chunk by chunk, writing each
    finished sequence's MOT file the moment it is delivered (BEFORE the
    covering checkpoint commits — at-least-once; a resumed run may
    re-write identical files, never miss one)."""
    from repro.serve import TrackingService

    frames = [0]

    def on_result(_idx, tracks):
        mot.write_results(os.path.join(args.out, f"{tracks.name}.txt"),
                          tracks.boxes, tracks.uid, tracks.emit)
        frames[0] += tracks.num_frames

    if args.resume:
        svc = TrackingService.resume(sched, args.ckpt_dir,
                                     ckpt_every=args.ckpt_every,
                                     on_result=on_result)
    else:
        svc = TrackingService(sched, ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every,
                              on_result=on_result)
        for name, db, dm, dc, de in seqs:
            await svc.submit(name, db, dm, det_class=dc, det_embed=de)
        if svc.ckpt is not None:
            svc.checkpoint(wait=True)   # pre-flight: resume always has a step
    chunks = 0
    while svc.busy:
        await svc.step()
        chunks += 1
        if args.kill_after_chunks is not None and \
                chunks >= args.kill_after_chunks:
            svc.close()                 # flush the in-flight write, then die
            os.kill(os.getpid(), signal.SIGKILL)
    svc.close()
    return frames[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--det-dir", default=None,
                    help="directory of MOT15 det.txt files")
    ap.add_argument("--out", default="/tmp/sort_out")
    ap.add_argument("--replicate", type=int, default=1,
                    help="paper §VI: replicate inputs k times")
    ap.add_argument("--lanes", type=int, default=4,
                    help="fixed lane budget the ragged sequences are "
                         "multiplexed onto (recycled as sequences end)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="frames planned/dispatched per host round-trip")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic lane budget (DESIGN.md §8): autoscale "
                         "between --min-lanes and --lanes over a "
                         "pre-compiled power-of-two width ladder — grow "
                         "on queue pressure, shrink once evacuating "
                         "lanes drain; outputs stay bit-identical to the "
                         "fixed --lanes run")
    ap.add_argument("--min-lanes", type=int, default=None,
                    help="ladder floor for --autoscale (default: "
                         "--lanes // 4 when that forms a power-of-two "
                         "ladder, raised until it divides --devices, "
                         "else --lanes); --lanes must be min * 2**k")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the lane budget over this many devices "
                         "(1-D 'lanes' mesh, DESIGN.md §7; --lanes must "
                         "divide evenly; on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first)")
    ap.add_argument("--fused", action="store_true",
                    help="lane-persistent fused frame path "
                         "(SortConfig.use_kernels=True): one kernel "
                         "dispatch per frame")
    ap.add_argument("--chunk-kernel", action="store_true",
                    help="chunk-resident megakernel (DESIGN.md §9, "
                         "SortConfig.chunk_kernel=True; implies --fused): "
                         "each planned --chunk-frame serving chunk runs "
                         "as ONE kernel dispatch with lane state resident "
                         "across the in-kernel frame loop — bit-identical "
                         "outputs, F-to-1 dispatch reduction "
                         "(configs/sort_mot.py::MEGAKERNEL)")
    ap.add_argument("--assoc", choices=("hungarian", "greedy"),
                    default="hungarian",
                    help="association algorithm (DESIGN.md §6): "
                         "'hungarian' is the paper's optimal assignment "
                         "(on the fused path its JV solve runs as a "
                         "jitted lane-batched stage); 'greedy' is the "
                         "cheaper in-kernel best-first matcher")
    ap.add_argument("--cost", choices=("iou", "iou+maha", "iou+embed"),
                    default="iou",
                    help="association cost (DESIGN.md §10): pure IoU "
                         "(the paper's, default), IoU with a chi-square "
                         "Mahalanobis gate, or IoU composed with an "
                         "appearance-embedding dot product")
    ap.add_argument("--classes", type=int, default=1,
                    help="class-partitioned association (DESIGN.md §10): "
                         "cross-class det/track pairs are masked "
                         "infeasible, so the single lane-batched "
                         "assignment solves the per-class block-diagonal "
                         "problem — no per-class loop, no extra "
                         "dispatches; 1 = single-class (default)")
    ap.add_argument("--embed-dim", type=int, default=8,
                    help="appearance embedding width for --cost iou+embed")
    ap.add_argument("--serve", action="store_true",
                    help="run through the TrackingService front-end "
                         "(DESIGN.md §11): async bounded admission, "
                         "circuit-broken dispatch, and — with "
                         "--ckpt-dir — crash-exact checkpoint/restore")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory for --serve; full service "
                         "state snapshots at chunk boundaries")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint every N chunk boundaries")
    ap.add_argument("--resume", action="store_true",
                    help="resume --serve from the latest committed "
                         "checkpoint in --ckpt-dir instead of submitting "
                         "fresh work; resumed outputs are bit-identical "
                         "to an uninterrupted run")
    ap.add_argument("--kill-after-chunks", type=int, default=None,
                    help="SIGKILL this process after N dispatched chunks "
                         "(crash injection for the kill-and-resume soak; "
                         "exits 137)")
    args = ap.parse_args()
    if args.min_lanes is not None and not args.autoscale:
        ap.error("--min-lanes only applies with --autoscale "
                 "(a fixed budget is just --lanes)")
    if (args.resume or args.kill_after_chunks is not None) and \
            not (args.serve and args.ckpt_dir):
        ap.error("--resume/--kill-after-chunks need --serve and --ckpt-dir")

    spec = cost_mod.parse_cost(args.cost, embed_dim=args.embed_dim)
    seqs = load_or_synthesize(args.det_dir, num_classes=args.classes,
                              embed_dim=spec.embed_dim)
    if args.replicate > 1:
        reps = []
        for r in range(args.replicate):
            reps += [(f"{name}#{r}",) + rest
                     for name, *rest in (tuple(s) for s in seqs)]
        seqs = reps
    os.makedirs(args.out, exist_ok=True)

    d = max(db.shape[1] for _, db, *_ in seqs)
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                use_kernels=args.fused or args.chunk_kernel,
                                chunk_kernel=args.chunk_kernel,
                                assoc=args.assoc, cost=spec,
                                num_classes=args.classes))
    mesh = lane_mesh(args.devices) if args.devices > 1 else None
    min_lanes = max_lanes = None
    if args.autoscale:
        max_lanes = args.lanes
        min_lanes = args.min_lanes
        if min_lanes is None:       # largest 4x headroom that stays a ladder
            min_lanes = args.lanes // 4 if args.lanes % 4 == 0 else args.lanes
            while min_lanes % args.devices and min_lanes < args.lanes:
                min_lanes *= 2  # every width must divide the mesh;
                # doubling stays on-ladder and stops at --lanes (an
                # indivisible --lanes fails scheduler validation anyway)
    sched = StreamScheduler(eng, num_lanes=min_lanes or args.lanes,
                            max_dets=d, chunk=args.chunk, mesh=mesh,
                            min_lanes=min_lanes, max_lanes=max_lanes)

    t_start = time.perf_counter()
    if args.serve:
        total_frames = asyncio.run(_serve(sched, seqs, args))
    else:
        for name, db, dm, dc, de in seqs:
            sched.submit(name, db, dm, det_class=dc, det_embed=de)
        total_frames = 0
        for tracks in sched.run():              # drains in submission order
            mot.write_results(os.path.join(args.out, f"{tracks.name}.txt"),
                              tracks.boxes, tracks.uid, tracks.emit)
            total_frames += tracks.num_frames
    dt = time.perf_counter() - t_start
    mode = ("chunk-resident megakernel" if args.chunk_kernel
            else "fused lane-persistent" if args.fused
            else "per-phase") + f" / {args.assoc} / {args.cost}"
    if args.classes > 1:
        mode += f" / {args.classes} classes"
    if args.devices > 1:
        mode += f" / {args.devices}-device lane mesh"
    lanes_str = f"{args.lanes} lanes"
    if args.autoscale:
        lanes_str = (f"elastic {sched.ladder[0]}-{sched.ladder[-1]} lanes, "
                     f"{len(sched.resizes)} resizes")
    print(f"{len(seqs)} sequences, {total_frames} frames in {dt:.2f}s "
          f"-> {total_frames / dt:,.0f} FPS (incl. compile, {mode}, "
          f"{lanes_str} at {sched.utilization:.0%} utilization)  "
          f"results in {args.out}")


if __name__ == "__main__":
    main()
