"""End-to-end tracking service — the paper's workload as a deployable driver.

Ingests MOT15-format detection files (or synthesizes Table-I-shaped ones),
length-buckets them (straggler mitigation), packs each bucket into a dense
stream batch, runs the jitted SORT engine, and writes MOT15 submission
files — the full Algorithm 1 pipeline, throughput-parallel over streams.

    PYTHONPATH=src python examples/tracking_service.py --replicate 4 \
        --out /tmp/sort_out
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine
from repro.data import mot, stream
from repro.data.synthetic import SceneConfig, generate_scene


def load_or_synthesize(det_dir):
    seqs = []
    if det_dir and os.path.isdir(det_dir):
        for name in sorted(os.listdir(det_dir)):
            if name.endswith(".txt"):
                db, dm = mot.read_det_file(os.path.join(det_dir, name))
                seqs.append((name[:-4], db, dm))
    if not seqs:  # synthesize the 11 paper sequences
        for i, (name, (frames, max_obj)) in enumerate(mot.TABLE_I.items()):
            _, _, db, dm = generate_scene(
                SceneConfig(num_frames=frames, max_objects=max_obj, seed=i))
            seqs.append((name, db, dm))
    return seqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--det-dir", default=None,
                    help="directory of MOT15 det.txt files")
    ap.add_argument("--out", default="/tmp/sort_out")
    ap.add_argument("--replicate", type=int, default=1,
                    help="paper §VI: replicate inputs k times")
    ap.add_argument("--buckets", type=int, default=3)
    ap.add_argument("--fused", action="store_true",
                    help="lane-persistent fused frame path "
                         "(SortConfig.use_kernels=True): one kernel "
                         "dispatch per frame, greedy association")
    args = ap.parse_args()

    seqs = load_or_synthesize(args.det_dir)
    if args.replicate > 1:
        seqs = stream.replicate(seqs, args.replicate)
    os.makedirs(args.out, exist_ok=True)

    total_frames = 0
    t_start = time.perf_counter()
    for bucket in stream.length_buckets(seqs, num_buckets=args.buckets):
        batch = stream.pack(bucket, pad_multiple=1)
        f, s, d, _ = batch.det_boxes.shape
        eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                    use_kernels=args.fused))
        state = eng.init(s)
        _, out = jax.jit(eng.run)(state, jnp.asarray(batch.det_boxes),
                                  jnp.asarray(batch.det_mask))
        jax.block_until_ready(out.boxes)
        for i, name in enumerate(batch.names):
            fi = int(batch.frame_valid[:, i].sum())
            mot.write_results(os.path.join(args.out, f"{name}.txt"),
                              np.asarray(out.boxes[:fi, i]),
                              np.asarray(out.uid[:fi, i]),
                              np.asarray(out.emit[:fi, i]))
            total_frames += fi
        print(f"bucket: {s} streams x {f} frames done")
    dt = time.perf_counter() - t_start
    mode = "fused lane-persistent" if args.fused else "per-phase"
    print(f"{len(seqs)} sequences, {total_frames} frames in {dt:.2f}s "
          f"-> {total_frames / dt:,.0f} FPS (incl. compile, {mode})  "
          f"results in {args.out}")


if __name__ == "__main__":
    main()
