"""Train a ~100M-param LM for a few hundred steps end-to-end (CPU-friendly).

Uses the full production driver (``repro.launch.train``): synthetic token
pipeline, AdamW, async checkpointing, crash-safe resume.

    PYTHONPATH=src python examples/train_lm.py            # ~100M params
    PYTHONPATH=src python examples/train_lm.py --tiny     # smoke scale
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.tiny:
        argv = ["--arch", "qwen1.5-0.5b", "--smoke",
                "--steps", str(args.steps or 60), "--batch", "8",
                "--seq", "64", "--lr", "3e-3",
                "--ckpt-dir", "/tmp/repro_train_tiny", "--resume"]
    else:
        # qwen1.5-0.5b full config at short sequence: ~100M-scale active
        # compute per step; a few hundred steps of real training.
        argv = ["--arch", "qwen1.5-0.5b",
                "--steps", str(args.steps or 300), "--batch", "4",
                "--seq", "256", "--lr", "1e-3", "--microbatches", "2",
                "--ckpt-dir", "/tmp/repro_train_100m", "--ckpt-every", "100",
                "--resume"]
    final_loss = train_main(argv)
    print(f"done; final loss {final_loss:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
