"""Continuous-batching LM serving demo — tracker slots as request slots.

The decode loop reuses ``repro.core.slots`` (the SORT tracker lifecycle)
for admission/eviction: requests are born into free slots, decode steps are
always dense over all lanes, finished sequences free their slot immediately
(the paper's throughput-scaling discipline applied to serving).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs import registry
from repro.models.model import build_model
from repro.models.transformer import Parallel
from repro.train.serve_step import ServeLoop


def main():
    cfg = registry.get_smoke("qwen2-7b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    loop = ServeLoop(model=model, params=params, par=Parallel.local(),
                     num_slots=4, cache_len=64, eos_id=7)
    prompts = [[1, 2, 3], [9, 8], [4, 4, 4, 4], [5], [6, 2], [3, 3, 1]]
    for p in prompts:
        loop.submit(p)
    print(f"{len(prompts)} requests submitted into 4 slots "
          f"(2 queued -> back-pressure)")

    for step in range(24):
        live = loop.step()
        if step % 6 == 0:
            print(f"step {step:2d}: {len(live)} active, "
                  f"{len(loop.outputs)} total served")
    print("generated token streams (uid -> tokens):")
    for uid, toks in sorted(loop.outputs.items()):
        print(f"  {uid}: {toks[:12]}{'...' if len(toks) > 12 else ''}")


if __name__ == "__main__":
    main()
