"""Ablation: Hungarian (paper) vs greedy association, on both engine paths.

The paper commits to the Hungarian method; PR 3 made it available inside
the fused lane-resident frame step (DESIGN.md §6), so the ablation now
spans a 2x2 grid — (unfused | fused) x (hungarian | greedy) — and doubles
as the Table IV/V analogue for the association stage: per-config frame
latency plus the per-frame dispatch accounting of each path.

Run via ``benchmarks.run`` (appended section) or standalone; CI smokes it
with a small ``num_frames`` so the fused-Hungarian rows cannot rot.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine, metrics
from repro.data.synthetic import SceneConfig, generate_scene

# (row tag, use_kernels, assoc) — the grid the ISSUE's Table IV/V analogue
# asks for: fused-Hungarian vs unfused-Hungarian vs fused-greedy (plus the
# original unfused-greedy baseline for the full square).
CONFIGS = (
    ("unfused_hungarian", False, "hungarian"),
    ("unfused_greedy", False, "greedy"),
    ("fused_hungarian", True, "hungarian"),
    ("fused_greedy", True, "greedy"),
)


def _dispatch_note(use_kernels: bool, assoc: str) -> str:
    """Per-frame device dispatch accounting (DESIGN.md §4/§6)."""
    if not use_kernels:
        return "dispatches/frame=per-phase XLA ops (layout round-trips)"
    on_tpu = jax.default_backend() == "tpu"
    if not on_tpu:
        return "dispatches/frame=1 XLA program (cpu lane oracle)"
    if assoc == "hungarian":
        return "dispatches/frame=1 pallas_call + jitted JV stage (no host)"
    return "dispatches/frame=1 pallas_call (greedy in-kernel)"


def run(seed: int = 0, num_frames: int = 150):
    rows = []
    for difficulty, kw in (
            ("easy", dict(miss_rate=0.02, fp_rate=0.05, det_noise=1.0,
                          max_objects=6)),
            ("dense", dict(miss_rate=0.1, fp_rate=0.5, det_noise=4.0,
                           max_objects=12))):
        cfg = SceneConfig(num_frames=num_frames, seed=seed, **kw)
        gt_boxes, gt_mask, db, dm = generate_scene(cfg)
        d = db.shape[1]
        dbj = jnp.asarray(db[:, None])
        dmj = jnp.asarray(dm[:, None])
        for tag, use_kernels, assoc in CONFIGS:
            eng = SortEngine(SortConfig(max_trackers=24, max_detections=d,
                                        use_kernels=use_kernels,
                                        assoc=assoc))
            run_fn = jax.jit(eng.run)
            jax.block_until_ready(run_fn(eng.init(1), dbj, dmj))
            t0 = time.perf_counter()
            _, out = run_fn(eng.init(1), dbj, dmj)
            jax.block_until_ready(out.boxes)
            dt = time.perf_counter() - t0
            m = metrics.mota(gt_boxes, gt_mask, np.asarray(out.boxes[:, 0]),
                             np.asarray(out.uid[:, 0]),
                             np.asarray(out.emit[:, 0]))
            rows.append((f"ablation/{difficulty}_{tag}_mota", m["mota"],
                         f"idsw={m['id_switches']}"))
            rows.append((f"ablation/{difficulty}_{tag}_us_per_frame",
                         dt / num_frames * 1e6,
                         f"mota={m['mota']:.3f} "
                         + _dispatch_note(use_kernels, assoc)))
    return rows


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value:.4f},{derived}")
