"""Ablation: Hungarian (paper) vs greedy association.

The paper commits to the Hungarian method; this quantifies what optimality
buys on Table-I-shaped workloads of increasing difficulty.  Run via
``benchmarks.run`` (appended section) or standalone.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine, metrics
from repro.core.greedy import greedy_iou_fn_for_engine
from repro.data.synthetic import SceneConfig, generate_scene


def run(seed=0):
    rows = []
    for difficulty, kw in (
            ("easy", dict(miss_rate=0.02, fp_rate=0.05, det_noise=1.0,
                          max_objects=6)),
            ("dense", dict(miss_rate=0.1, fp_rate=0.5, det_noise=4.0,
                           max_objects=12))):
        cfg = SceneConfig(num_frames=150, seed=seed, **kw)
        gt_boxes, gt_mask, db, dm = generate_scene(cfg)
        d = db.shape[1]
        for name, assoc in (("hungarian", None),
                            ("greedy", greedy_iou_fn_for_engine(0.3))):
            eng = SortEngine(SortConfig(max_trackers=24, max_detections=d),
                             assoc_fn=assoc)
            run_fn = jax.jit(eng.run)
            st = eng.init(1)
            dbj = jnp.asarray(db[:, None])
            dmj = jnp.asarray(dm[:, None])
            jax.block_until_ready(run_fn(st, dbj, dmj))
            t0 = time.perf_counter()
            _, out = run_fn(eng.init(1), dbj, dmj)
            jax.block_until_ready(out.boxes)
            dt = time.perf_counter() - t0
            m = metrics.mota(gt_boxes, gt_mask, np.asarray(out.boxes[:, 0]),
                             np.asarray(out.uid[:, 0]),
                             np.asarray(out.emit[:, 0]))
            rows.append((f"ablation/{difficulty}_{name}_mota", m["mota"],
                         f"idsw={m['id_switches']} "
                         f"us_per_frame={dt / 150 * 1e6:.0f}"))
    return rows


