"""Service soak — the crash-exact front-end's overhead and recovery cost.

The scheduler benchmarks (``ragged``, ``autoscale``) price the lane
multiplexing; this one prices what production puts around it
(DESIGN.md §11): the :class:`repro.serve.TrackingService` front-end with
chunk-boundary checkpointing, admission bounds, and a circuit breaker.

One soak, four questions:

* **service overhead** — served throughput with checkpointing OFF vs the
  bare scheduler loop (the async/admission/delivery tax alone);
* **checkpoint tax** — served throughput with a full-state checkpoint at
  every chunk boundary vs checkpointing off (the double-buffered async
  writer should hide most of the disk time), plus the mean synchronous
  export+commit latency;
* **resume latency** — time from ``TrackingService.resume`` to the first
  delivered sequence of a mid-run checkpoint (the recovery-time term of
  the crash story);
* **shed behaviour** — an over-rate burst against a token bucket: every
  over-budget submission sheds with a positive ``retry_after`` hint and
  the pending count never exceeds the bound.
"""
from __future__ import annotations

import asyncio
import tempfile
import time

import numpy as np

from repro.core import SortConfig, SortEngine
from repro.data.synthetic import SceneConfig, generate_scene
from repro.serve import Overloaded, StreamScheduler, TrackingService


def _sequences(n: int, frames: int, seed: int):
    seqs = []
    for k in range(n):
        _, _, db, dm = generate_scene(SceneConfig(
            num_frames=frames, max_objects=8, seed=seed + k))
        seqs.append((f"seq{k}", db, dm))
    d = max(db.shape[1] for _, db, _ in seqs)
    return [(n_, np.pad(db, ((0, 0), (0, d - db.shape[1]), (0, 0))),
             np.pad(dm, ((0, 0), (0, d - dm.shape[1])))) for n_, db, dm
            in seqs], d


def _mk_sched(eng, d, num_lanes, chunk):
    return StreamScheduler(eng, num_lanes=num_lanes, max_dets=d, chunk=chunk)


async def _serve_all(svc, seqs) -> float:
    t0 = time.perf_counter()
    for s in seqs:
        await svc.submit(*s)
    await svc.drain()
    svc.close()
    return time.perf_counter() - t0


def run(num_seqs: int = 8, frames: int = 60, num_lanes: int = 4,
        chunk: int = 16, seed: int = 0, use_kernels: bool = False,
        json_dir: str | None = None):
    seqs, d = _sequences(num_seqs, frames, seed)
    real_frames = num_seqs * frames
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                use_kernels=use_kernels))

    # bare scheduler baseline (warm rep 0, time rep 1)
    for rep in range(2):
        sched = _mk_sched(eng, d, num_lanes, chunk)
        for s in seqs:
            sched.submit(*s)
        t0 = time.perf_counter()
        list(sched.run())
        t_bare = time.perf_counter() - t0

    # service, checkpointing off
    t_svc = asyncio.run(_serve_all(
        TrackingService(_mk_sched(eng, d, num_lanes, chunk)), seqs))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # service, full-state checkpoint at every chunk boundary
        t_ckpt = asyncio.run(_serve_all(
            TrackingService(_mk_sched(eng, d, num_lanes, chunk),
                            ckpt_dir=ckpt_dir, ckpt_every=1), seqs))

        # synchronous checkpoint latency + resume latency, mid-run
        async def _mid_run():
            svc = TrackingService(_mk_sched(eng, d, num_lanes, chunk),
                                  ckpt_dir=ckpt_dir, ckpt_every=1)
            for s in seqs:
                await svc.submit(*s)
            for _ in range(3):
                await svc.step()
            t0 = time.perf_counter()
            svc.checkpoint(wait=True)
            dt_commit = time.perf_counter() - t0
            svc.close()
            return dt_commit

        dt_commit = asyncio.run(_mid_run())

        async def _resume():
            t0 = time.perf_counter()
            svc = TrackingService.resume(
                _mk_sched(eng, d, num_lanes, chunk), ckpt_dir)
            while svc.busy and not svc.completed:
                await svc.step()
            dt_first = time.perf_counter() - t0
            await svc.drain()
            svc.close()
            return dt_first

        dt_resume = asyncio.run(_resume())

    # shed behaviour: over-rate burst against a 1-token bucket
    async def _burst():
        svc = TrackingService(_mk_sched(eng, d, num_lanes, chunk),
                              rate=1.0, burst=1.0, max_pending=num_seqs)
        shed, hints, peak = 0, [], 0
        for s in seqs:
            try:
                await svc.submit(*s)
            except Overloaded as e:
                shed += 1
                hints.append(e.retry_after)
            peak = max(peak, svc.pending)
        await svc.drain()
        svc.close()
        return shed, hints, peak

    shed, hints, peak = asyncio.run(_burst())
    assert shed == num_seqs - 1 and all(h > 0 for h in hints), \
        "over-rate burst must shed with positive Retry-After hints"
    assert peak <= num_seqs, "pending exceeded the admission bound"

    fps = {k: real_frames / t for k, t in
           (("bare", t_bare), ("svc", t_svc), ("ckpt", t_ckpt))}
    rows = [
        ("service/bare_us_per_frame", t_bare / real_frames * 1e6,
         f"fps={fps['bare']:,.0f} (scheduler loop, no front-end)"),
        ("service/served_us_per_frame", t_svc / real_frames * 1e6,
         f"fps={fps['svc']:,.0f} overhead={t_svc / t_bare - 1:+.1%} "
         f"(async admission + delivery, no checkpoints)"),
        ("service/ckpt_us_per_frame", t_ckpt / real_frames * 1e6,
         f"fps={fps['ckpt']:,.0f} tax={t_ckpt / t_svc - 1:+.1%} "
         f"(full-state checkpoint every chunk, async writer)"),
        ("service/ckpt_commit_ms", dt_commit * 1e3,
         "synchronous export+commit of the full service state"),
        ("service/resume_to_first_result_ms", dt_resume * 1e3,
         "TrackingService.resume to first delivered sequence"),
        ("service/shed_rate", shed / num_seqs,
         f"over-rate burst: {shed}/{num_seqs} shed, mean "
         f"retry_after={np.mean(hints):.2f}s, peak pending={peak}"),
    ]
    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("service",
                    dict(num_seqs=num_seqs, frames=frames,
                         num_lanes=num_lanes, chunk=chunk, seed=seed,
                         use_kernels=use_kernels),
                    rows, json_dir)
    return rows


if __name__ == "__main__":
    for name, value, derived in run(json_dir="."):
        print(f"{name},{value:.4f},{derived}")
