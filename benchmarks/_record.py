"""Machine-readable benchmark artifacts — the ``BENCH_<name>.json`` trail.

The CSV the suite driver prints is for eyeballs; this module gives every
benchmark a machine-diffable artifact so future PRs can compare against a
*recorded* perf trajectory instead of re-deriving baselines from logs.
``benchmarks/run.py --json [DIR]`` turns it on for every section that
supports it (speedup, ragged, device_scaling, autoscale,
dispatch_overhead); each standalone ``__main__`` writes next to the CSV.

Schema (``schema_version`` 1) — one JSON object per benchmark::

    {
      "benchmark": "<name>",
      "schema_version": 1,
      "config": {<the run()'s knobs, so a diff knows the workload>},
      "metrics": {"<row_name>": {"value": <float>, "derived": "<str>"}},
      "timestamp": <unix seconds>
    }

``metrics`` keys are exactly the CSV row names, so the two outputs
cross-reference trivially.
"""
from __future__ import annotations

import json
import os
import time

SCHEMA_VERSION = 1


def _jsonable(v):
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    return str(v)


def write_bench(name: str, config: dict, rows, out_dir: str = ".") -> str:
    """Write ``rows`` (``[(row_name, value, derived), ...]`` — the exact
    list a benchmark ``run()`` returns) as ``out_dir/BENCH_<name>.json``;
    returns the written path."""
    doc = {
        "benchmark": name,
        "schema_version": SCHEMA_VERSION,
        "config": {k: _jsonable(v) for k, v in dict(config).items()},
        "metrics": {row_name: {"value": float(value), "derived": str(derived)}
                    for row_name, value, derived in rows},
        "timestamp": time.time(),
    }
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
