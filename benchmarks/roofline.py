"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads ``results/dryrun.json`` (produced by ``repro.launch.dryrun``) and for
every (arch x shape x mesh) cell derives the three per-device roofline
terms on TPU v5e constants:

    compute_s    = device_FLOPs / 197e12            (bf16 peak per chip)
    memory_s     = device_HBM_bytes / 819e9
    collective_s = effective_wire_bytes / 50e9      (per ICI link)

``device_FLOPs`` / ``HBM bytes`` / collective bytes come from the
loop-aware HLO analyzer (``repro.launch.hlo_analysis``), NOT from
``cost_analysis`` (which counts scan bodies once — see DESIGN.md).

Wire-byte factors per collective kind (ring algorithms, group size g):
    all-reduce       2 (g-1)/g * buffer      ~ 2x
    all-gather       (g-1)/g * result        ~ 1x result (gathered) bytes
    reduce-scatter   (g-1)   * result        (result is the scattered piece)
    all-to-all       (g-1)/g * result
    collective-permute  1x result

MODEL_FLOPS (the "useful work" yardstick):
    train:   tokens * 6 * N_mm(active)  + 12 * B * L^2 * H * hd * layers
    prefill: tokens * 2 * N_mm(active)  +  4 * B * L^2 * H * hd * layers
    decode:  B * 2 * N_mm(active)       +  4 * B * S * H * hd * layers
(N_mm = matmul-participating params; embedding gather excluded, LM head
included; MoE counts only routed-active + shared experts.)
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link


def wire_bytes(collectives: dict) -> float:
    total = 0.0
    for kind, v in collectives.items():
        if not isinstance(v, dict) or "bytes" not in v:
            continue
        g = max(v.get("group") or [2])
        b = v["bytes"]
        if kind == "all-reduce":
            total += 2.0 * (g - 1) / g * b
        elif kind == "all-gather":
            total += (g - 1) / g * b
        elif kind == "reduce-scatter":
            total += (g - 1) * b
        elif kind == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute
            total += b
    return total


def _n_mm(cfg) -> tuple:
    """(matmul params total, matmul params active) — see module docstring."""
    v, d = cfg.padded_vocab, cfg.d_model
    total = cfg.num_params()
    embed = v * d
    head = v * d
    trunk = total - embed - (head if not cfg.tie_embeddings else 0)
    n_mm = trunk + head
    active = n_mm
    if cfg.moe:
        moe_layers = cfg.num_layers - cfg.first_k_dense
        routed_total = moe_layers * cfg.n_routed_experts * 3 * d * cfg.moe_d_ff
        routed_active = moe_layers * cfg.moe_top_k * 3 * d * cfg.moe_d_ff
        active = n_mm - routed_total + routed_active
    return n_mm, active


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import registry
    cfg = registry.get_arch(arch)
    shape = registry.SHAPES[shape_name]
    b, l = shape.global_batch, shape.seq_len
    _, n_act = _n_mm(cfg)
    h_hd = (cfg.n_heads * cfg.head_dim_
            if cfg.block_type in ("attn", "hybrid") and cfg.attn_type == "gqa"
            else (cfg.n_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
                  if cfg.attn_type == "mla" and cfg.block_type == "attn"
                  else 0))
    if cfg.block_type == "hybrid":
        h_hd = cfg.n_heads * cfg.head_dim_
    nl = cfg.num_layers
    if shape.kind == "train":
        attn = 12.0 * b * l * l * h_hd * nl
        if cfg.sliding_window:  # windowed layers touch only L*W pairs
            n_glob = len(cfg.global_attn_layers)
            attn = 12.0 * b * l * h_hd * (
                n_glob * l + (nl - n_glob) * min(cfg.sliding_window, l))
        return b * l * 6.0 * n_act + attn
    if shape.kind == "prefill":
        attn = 4.0 * b * l * l * h_hd * nl
        if cfg.sliding_window:
            n_glob = len(cfg.global_attn_layers)
            attn = 4.0 * b * l * h_hd * (
                n_glob * l + (nl - n_glob) * min(cfg.sliding_window, l))
        return b * l * 2.0 * n_act + attn
    # decode
    attn = 4.0 * b * l * h_hd * nl
    if cfg.sliding_window:
        n_glob = len(cfg.global_attn_layers)
        attn = 4.0 * b * h_hd * (n_glob * l
                                 + (nl - n_glob) * min(cfg.sliding_window, l))
    if cfg.attn_type == "mla" and cfg.block_type == "attn":
        # absorbed decode reads the compressed cache: per token ~ H*(r+dr)*S
        attn = 4.0 * b * l * cfg.n_heads * (cfg.kv_lora_rank
                                            + cfg.qk_rope_head_dim) * nl
    if cfg.block_type == "ssm":
        attn = 6.0 * b * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * nl
    return b * 2.0 * n_act + attn


def analyze(results: dict) -> list:
    rows = []
    for key, v in sorted(results.items()):
        if not v.get("ok"):
            rows.append({"key": key, "ok": False})
            continue
        dev = v["devices"]
        c_s = v["flops"] / PEAK_FLOPS
        m_s = v["hbm_bytes"] / HBM_BW
        w = wire_bytes(v["collectives"])
        k_s = w / LINK_BW
        dom = max(("compute", c_s), ("memory", m_s),
                  ("collective", k_s), key=lambda t: t[1])[0]
        mf = model_flops(v["arch"], v["shape"]) / dev
        step_s = max(c_s, m_s, k_s)  # perfectly-overlapped bound
        rows.append({
            "key": key, "ok": True, "arch": v["arch"], "shape": v["shape"],
            "mesh": v["mesh"], "devices": dev,
            "compute_s": c_s, "memory_s": m_s, "collective_s": k_s,
            "wire_bytes": w, "dominant": dom,
            "model_flops_dev": mf, "hlo_flops_dev": v["flops"],
            "useful_ratio": mf / max(v["flops"], 1.0),
            "step_bound_s": step_s,
            "roofline_fraction": (mf / PEAK_FLOPS) / max(step_s, 1e-30),
            "hint": _hint(dom, v),
        })
    return rows


def _hint(dom: str, v: dict) -> str:
    if dom == "compute":
        return ("compute-bound: raise useful-FLOP ratio (less remat/capacity "
                "waste) or accept — this is the healthy regime")
    if dom == "memory":
        return ("HBM-bound: shrink resident bytes (bf16 carries, fused "
                "softmax-xent, windowed caches) or raise arithmetic "
                "intensity per pass")
    return ("collective-bound: reshard to cut wire bytes (FSDP gather "
            "granularity, a2a capacity factor, head padding), overlap "
            "collectives with compute")


def table(rows, mesh_filter=None) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful FLOP ratio | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if not r.get("ok") or (mesh_filter and r["mesh"] != mesh_filter):
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |\n")
    return "".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    with open(args.dryrun) as fh:
        results = json.load(fh)
    rows = analyze(results)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(rows, fh, indent=1)
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        print(f"\n## mesh {mesh}\n")
        print(table(rows, mesh))
    bad = [r for r in rows if not r.get("ok")]
    print(f"{len(rows) - len(bad)} cells analyzed, {len(bad)} failed")


if __name__ == "__main__":
    main()
