"""Multi-class association sweep — composed costs vs the single-class baseline.

The class partition (DESIGN.md §10) folds per-class association into ONE
lane-batched solve by masking cross-class pairs infeasible, so K classes
cost the same dispatches as one.  This benchmark quantifies that claim on
the paper's extremely-small-matrix regime: per-frame latency of the
single-class IoU baseline vs the class-partitioned composed costs
({iou, iou+maha, iou+embed} x {1, 3} classes) on the fused lane path,
same synthetic scene geometry throughout.  The derived column carries the
per-run emitted-track count so a cost/partition change that silently
alters tracking behaviour shows up next to its latency.

Run via ``benchmarks.run`` (section ``multiclass``) or standalone;
``--json`` / ``json_dir`` writes ``BENCH_multiclass.json``
(``benchmarks/_record.py`` schema).  CI smokes it with a small
``num_frames`` so the multi-class rows cannot rot.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine, cost as cost_mod
from repro.data.synthetic import SceneConfig, generate_multiclass_scene

EMBED_DIM = 8

# (row tag, CostSpec, num_classes): the single-class IoU row is the exact
# pre-multiclass engine trace (bit-identity contract, DESIGN.md §10) —
# every other row is measured against it.
CONFIGS = (
    ("iou_1cls", cost_mod.IOU, 1),
    ("iou_3cls", cost_mod.IOU, 3),
    ("iou_maha_3cls", cost_mod.iou_maha(), 3),
    ("iou_embed_3cls", cost_mod.iou_embed(EMBED_DIM), 3),
)


def run(seed: int = 0, num_frames: int = 150, json_dir: str | None = None):
    scene = SceneConfig(num_frames=num_frames, max_objects=10,
                        miss_rate=0.05, fp_rate=0.2, det_noise=2.0,
                        seed=seed)
    _, _, _, db, dm, dc, de = generate_multiclass_scene(
        scene, num_classes=3, embed_dim=EMBED_DIM)
    d = db.shape[1]
    dbj = jnp.asarray(db[:, None])
    dmj = jnp.asarray(dm[:, None])
    dcj = jnp.asarray(dc[:, None])
    dej = jnp.asarray(de[:, None])

    rows = []
    base_us = None
    for tag, spec, nc in CONFIGS:
        eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                    use_kernels=True, cost=spec,
                                    num_classes=nc))
        kw = {}
        if nc > 1:
            kw["det_class"] = dcj
        if spec.uses_embed:
            kw["det_embed"] = dej
        run_fn = jax.jit(lambda s, b, m, eng=eng, kw=kw:
                         eng.run(s, b, m, **kw))
        jax.block_until_ready(run_fn(eng.init(1), dbj, dmj))
        t0 = time.perf_counter()
        _, out = run_fn(eng.init(1), dbj, dmj)
        jax.block_until_ready(out.boxes)
        us = (time.perf_counter() - t0) / num_frames * 1e6
        if base_us is None:
            base_us = us
        emitted = int(np.asarray(out.emit).sum())
        rows.append((f"multiclass/{tag}_us_per_frame", us,
                     f"x{us / base_us:.2f} vs 1-class iou, "
                     f"emitted={emitted}, one lane-batched solve "
                     f"(block-diagonal via feasibility mask)"))
    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("multiclass",
                    dict(seed=seed, num_frames=num_frames,
                         max_detections=d, embed_dim=EMBED_DIM,
                         configs=[f"{t}" for t, _, _ in CONFIGS]),
                    rows, json_dir)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR")
    ap.add_argument("--frames", type=int, default=150)
    args = ap.parse_args()
    for name, value, derived in run(num_frames=args.frames,
                                    json_dir=args.json):
        print(f"{name},{value:.4f},{derived}")
