"""Benchmark driver — one section per paper table.

Prints ``name,us_per_call,derived`` CSV.  Table mapping:

* Table I   -> benchmarks.datasets   (11 MOT15-shaped sequences, FPS+MOTA)
* Table IV  -> benchmarks.kernel_ai  (per-phase time share + AI)
* Table V   -> benchmarks.speedup    (per-op Python vs fused batched JAX)
* Table VI  -> benchmarks.scaling    (strong vs weak vs throughput)

``--json [DIR]`` additionally writes ``BENCH_<name>.json`` artifacts
(schema in ``benchmarks/_record.py``) for the sections that support
them: speedup, ragged, autoscale, device_scaling, dispatch_overhead.

Roofline (§Roofline, from the dry-run) lives in ``benchmarks.roofline`` —
run it separately after ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    from benchmarks import (association_ablation, autoscale, datasets,
                            device_scaling, dispatch_overhead, kernel_ai,
                            multiclass, ragged, scaling, service_soak,
                            speedup)

    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="Run every benchmark section; prints CSV to stdout.")
    ap.add_argument(
        "--json", nargs="?", const=".", default=None, metavar="DIR",
        help="also write BENCH_<name>.json artifacts to DIR (default: cwd) "
             "for the sections that support them")
    args = ap.parse_args(argv)

    # (section, run_fn, emits BENCH_<name>.json under --json)
    sections = [
        ("tableI", datasets.run, False),
        ("tableIV", kernel_ai.run, False),
        ("tableV", speedup.run, True),
        ("tableVI", scaling.run, False),
        ("ragged", ragged.run, True),
        ("ablation", association_ablation.run, False),
        # elastic vs fixed lane budgets on a bursty 4-phase arrival trace
        # (DESIGN.md §8)
        ("autoscale", autoscale.run, True),
        # reports per-device rows only up to jax.device_count(); export
        # XLA_FLAGS=--xla_force_host_platform_device_count=8 for the full
        # {1,2,4,8} sweep on CPU (DESIGN.md §7)
        ("devices", device_scaling.run, True),
        # per-frame scan vs chunk-resident megakernel dispatch accounting
        # (DESIGN.md §9)
        ("dispatch", dispatch_overhead.run, True),
        # composed costs x class partition vs the single-class IoU
        # baseline — one block-diagonal lane-batched solve (DESIGN.md §10)
        ("multiclass", multiclass.run, True),
        # TrackingService front-end: admission/delivery overhead,
        # chunk-boundary checkpoint tax, resume latency, shed behaviour
        # (DESIGN.md §11)
        ("service", service_soak.run, True),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn, emits_json in sections:
        kwargs = ({"json_dir": args.json}
                  if (args.json is not None and emits_json) else {})
        try:
            for row_name, value, derived in fn(**kwargs):
                print(f"{row_name},{value:.4f},{derived}")
                sys.stdout.flush()
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name}/ERROR,-1,see stderr")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
