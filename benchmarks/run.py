"""Benchmark driver — one section per paper table.

Prints ``name,us_per_call,derived`` CSV.  Table mapping:

* Table I   -> benchmarks.datasets   (11 MOT15-shaped sequences, FPS+MOTA)
* Table IV  -> benchmarks.kernel_ai  (per-phase time share + AI)
* Table V   -> benchmarks.speedup    (per-op Python vs fused batched JAX)
* Table VI  -> benchmarks.scaling    (strong vs weak vs throughput)

Roofline (§Roofline, from the dry-run) lives in ``benchmarks.roofline`` —
run it separately after ``repro.launch.dryrun``.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (association_ablation, autoscale, datasets,
                            device_scaling, kernel_ai, ragged, scaling,
                            speedup)

    sections = [
        ("tableI", datasets.run),
        ("tableIV", kernel_ai.run),
        ("tableV", speedup.run),
        ("tableVI", scaling.run),
        ("ragged", ragged.run),
        ("ablation", association_ablation.run),
        # elastic vs fixed lane budgets on a bursty 4-phase arrival trace
        # (DESIGN.md §8)
        ("autoscale", autoscale.run),
        # reports per-device rows only up to jax.device_count(); export
        # XLA_FLAGS=--xla_force_host_platform_device_count=8 for the full
        # {1,2,4,8} sweep on CPU (DESIGN.md §7)
        ("devices", device_scaling.run),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in sections:
        try:
            for row_name, value, derived in fn():
                print(f"{row_name},{value:.4f},{derived}")
                sys.stdout.flush()
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name}/ERROR,-1,see stderr")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
