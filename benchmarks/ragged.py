"""Ragged-traffic throughput: lane-recycling scheduler vs pad-to-max.

The paper's Table VI scales throughput by giving each worker one video
file — all workers busy because the 11 files were replicated to match the
core count.  Real traffic is ragged (Table I lengths span 71–1000 frames),
and the fixed-batch engine must pad every sequence in a batch to the
longest one, so a 4:1 length skew wastes most lane-steps on padding.

This benchmark runs the same 4:1 skewed mix (arrival-interleaved short and
long sequences, the adversarial order for batching) two ways at an equal
lane budget:

* **pad-to-max**: FIFO batches of ``num_lanes`` sequences, every sequence
  padded to the global maximum length, one ``SortEngine.run`` per batch —
  the serving strategy the fixed-batch API forces.
* **scheduler**: ``repro.serve.StreamScheduler`` — lanes recycled the
  moment a sequence ends, inactive lanes masked inside the fused step
  (DESIGN.md §3).

Throughput is *real* frames (no padding) per second, the end-to-end
serving metric Murray (arXiv:1709.03572) argues for.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine
from repro.data.synthetic import SceneConfig, generate_scene
from repro.serve import StreamScheduler


def _mix(num_seqs: int, long_frames: int, skew: int, seed: int):
    """Arrival-interleaved 4:1 mix: long, short, long, short, ..."""
    seqs = []
    for i in range(num_seqs):
        f = long_frames if i % 2 == 0 else max(1, long_frames // skew)
        _, _, db, dm = generate_scene(
            SceneConfig(num_frames=f, max_objects=8, seed=seed + i))
        seqs.append((f"seq{i}", db, dm))
    return seqs


def _pad_dets(seqs):
    d = max(s[1].shape[1] for s in seqs)
    out = []
    for name, db, dm in seqs:
        grow = d - db.shape[1]
        out.append((name, np.pad(db, ((0, 0), (0, grow), (0, 0))),
                    np.pad(dm, ((0, 0), (0, grow)))))
    return out, d


def _run_padmax(run_fn, eng, seqs, num_lanes: int, f_max: int, d: int) -> int:
    """FIFO batches of ``num_lanes``, every sequence padded to ``f_max``."""
    last = None
    for i in range(0, len(seqs), num_lanes):
        batch = seqs[i:i + num_lanes]
        det = np.zeros((f_max, num_lanes, d, 4), np.float32)
        msk = np.zeros((f_max, num_lanes, d), bool)
        for j, (_, db, dm) in enumerate(batch):
            det[:db.shape[0], j] = db
            msk[:dm.shape[0], j] = dm
        _, last = run_fn(eng.init(num_lanes), jnp.asarray(det),
                         jnp.asarray(msk))
    jax.block_until_ready(last.boxes)
    return -(-len(seqs) // num_lanes) * f_max * num_lanes  # lane-steps paid


def run(num_seqs: int = 16, long_frames: int = 120, skew: int = 4,
        num_lanes: int = 4, chunk: int = 32, seed: int = 0,
        repeats: int = 3, use_kernels: bool = True,
        json_dir: str | None = None):
    seqs, d = _pad_dets(_mix(num_seqs, long_frames, skew, seed))
    f_max = max(s[1].shape[0] for s in seqs)
    real_frames = sum(s[1].shape[0] for s in seqs)
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                use_kernels=use_kernels))

    def time_sched() -> tuple[float, float]:
        # one scheduler for all reps: a serving process compiles its chunk
        # program once and then handles traffic forever (lane state
        # persists, but every admission starts from a masked re-init)
        sched = StreamScheduler(eng, num_lanes=num_lanes,
                                max_dets=d, chunk=chunk)
        best = np.inf
        for rep in range(repeats + 1):         # first rep warms the jit
            t0 = time.perf_counter()
            for name, db, dm in seqs:
                sched.submit(name, db, dm)
            n_done = len(sched.run())
            dt = time.perf_counter() - t0
            assert n_done == num_seqs
            if rep > 0:
                best = min(best, dt)
        return best, sched.utilization

    def time_padmax() -> tuple[float, int]:
        run_fn = jax.jit(eng.run)              # compiled once, like serving
        _run_padmax(run_fn, eng, seqs, num_lanes, f_max, d)  # warm the jit
        best, paid = np.inf, 0
        for _ in range(repeats):
            t0 = time.perf_counter()
            paid = _run_padmax(run_fn, eng, seqs, num_lanes, f_max, d)
            best = min(best, time.perf_counter() - t0)
        return best, paid

    t_sched, util = time_sched()
    t_pad, pad_steps = time_padmax()
    fps_sched = real_frames / t_sched
    fps_pad = real_frames / t_pad
    rows = [
        ("ragged/padmax_us_per_frame", t_pad / real_frames * 1e6,
         f"fps={fps_pad:,.0f} lane_steps={pad_steps} "
         f"pad_waste={1 - real_frames / pad_steps:.0%}"),
        ("ragged/scheduler_us_per_frame", t_sched / real_frames * 1e6,
         f"fps={fps_sched:,.0f} lane_util={util:.0%} "
         f"(working steps only) lanes={num_lanes} chunk={chunk}"),
        ("ragged/scheduler_speedup", fps_sched / fps_pad,
         f"{skew}:1 length skew, {num_seqs} seqs, "
         f"{'fused' if use_kernels else 'per-phase'} path"),
    ]
    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("ragged",
                    dict(num_seqs=num_seqs, long_frames=long_frames,
                         skew=skew, num_lanes=num_lanes, chunk=chunk,
                         seed=seed, repeats=repeats,
                         use_kernels=use_kernels,
                         backend=jax.default_backend()),
                    rows, json_dir)
    return rows


if __name__ == "__main__":
    for name, value, derived in run(json_dir="."):
        print(f"{name},{value:.4f},{derived}")
