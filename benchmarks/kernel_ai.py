"""Paper Table IV analogue: per-phase compute kernels, time share, and
arithmetic intensity, derived from compiled HLO (flops / hbm bytes) plus
measured per-phase wall time on the host.

Paper reference values: predict AI 2.4 (30% time), assignment AI 1.5
(22.2%), update AI 18 (34.3%).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import association, bbox, kalman
from repro.core.hungarian import solve_masked
from repro.launch.hlo_analysis import analyze_text


def _measure(fn, *args, repeats=20):
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jfn(*args)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / repeats
    hlo = jfn.lower(*args).compile().as_text()
    a = analyze_text(hlo)
    total_flops = a["flops"] + a["eltwise_flops"]
    ai = total_flops / max(a["hbm_bytes"], 1.0)
    return dt, total_flops, a["hbm_bytes"], ai


def run(s=512, t=16, d=16, seed=0):
    rng = np.random.default_rng(seed)
    params = kalman.KalmanParams.default()
    x = jnp.asarray(rng.normal(size=(s, t, 7)).astype(np.float32))
    a = rng.normal(size=(s, t, 7, 7)).astype(np.float32)
    p = jnp.asarray(a @ a.transpose(0, 1, 3, 2)
                    + np.eye(7, dtype=np.float32))
    z = jnp.asarray(rng.normal(size=(s, t, 4)).astype(np.float32))
    m = jnp.asarray(rng.random((s, t)) < 0.7)
    det = jnp.asarray(rng.uniform(0, 500, size=(s, d, 4)).astype(np.float32))
    dmask = jnp.asarray(rng.random((s, d)) < 0.8)
    tmask = jnp.asarray(rng.random((s, t)) < 0.8)

    phases = {
        "predict": (lambda x, p: kalman.predict(x, p, params), (x, p)),
        "assign": (lambda dt_, dm, tb, tm: association.associate(
            dt_, dm, bbox.z_to_xyxy(x[..., :4]), tm), (det, dmask, det, tmask)),
        "update": (lambda x, p, z, m: kalman.masked_update(x, p, z, m,
                                                           params),
                   (x, p, z, m)),
        "output_prep": (lambda x: bbox.z_to_xyxy(x[..., :4]), (x,)),
    }
    rows = []
    times = {}
    for name, (fn, args) in phases.items():
        dt, flops, hbm, ai = _measure(fn, *args)
        times[name] = dt
        rows.append((f"tableIV/{name}_us", dt * 1e6,
                     f"AI={ai:.2f} flops={flops:.3g}"))
    total = sum(times.values())
    for name, dt in times.items():
        rows.append((f"tableIV/{name}_time_share", dt / total * 100.0,
                     "paper: predict 30 / assign 22.2 / update 34.3 (%)"))
    return rows
