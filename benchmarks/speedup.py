"""Paper Table V analogue: reference per-op Python SORT vs fused batched JAX.

The paper reports a 45-106x speedup of their C rewrite over the original
parallel-Python SORT.  Our analogue: the per-stream numpy/scipy reference
(same per-op dispatch pattern as the original) vs. the single fused jitted
batched engine, at equal work (same sequences).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine
from repro.core.ref_numpy import Sort as RefSort
from repro.data.synthetic import SceneConfig, generate_scene


def run(num_streams: int = 64, num_frames: int = 120, seed: int = 0,
        repeats: int = 3):
    scenes = [generate_scene(SceneConfig(num_frames=num_frames,
                                         max_objects=10, seed=seed + i))
              for i in range(num_streams)]
    d = max(s[2].shape[1] for s in scenes)
    det = np.zeros((num_frames, num_streams, d, 4), np.float32)
    msk = np.zeros((num_frames, num_streams, d), bool)
    for i, (_, _, db, dm) in enumerate(scenes):
        det[:, i, :db.shape[1]] = db
        msk[:, i, :dm.shape[1]] = dm

    # --- reference: per-stream, per-op numpy (original-Python shaped) ---
    n_ref_streams = min(num_streams, 8)  # don't wait forever
    t0 = time.perf_counter()
    for i in range(n_ref_streams):
        ref = RefSort()
        for t in range(num_frames):
            ref.update(det[t, i][msk[t, i]])
    t_ref = (time.perf_counter() - t0) / (n_ref_streams * num_frames)

    # --- ours: fused jitted batch ---
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d))
    state = eng.init(num_streams)
    run_fn = jax.jit(eng.run)
    db, dm = jnp.asarray(det), jnp.asarray(msk)
    jax.block_until_ready(run_fn(state, db, dm))  # compile
    best = np.inf
    for _ in range(repeats):
        st = eng.init(num_streams)
        t0 = time.perf_counter()
        out = run_fn(st, db, dm)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    t_ours = best / (num_streams * num_frames)

    return [
        ("tableV/ref_python_us_per_frame", t_ref * 1e6, ""),
        ("tableV/jax_batched_us_per_frame", t_ours * 1e6,
         f"speedup={t_ref / t_ours:.1f}x"),
        ("tableV/jax_batched_fps", 1.0 / t_ours,
         f"streams={num_streams}"),
    ]
