"""Paper Table V analogue: reference per-op Python SORT vs fused batched JAX.

The paper reports a 45-106x speedup of their C rewrite over the original
parallel-Python SORT.  Our analogue: the per-stream numpy/scipy reference
(same per-op dispatch pattern as the original) vs. the single fused jitted
batched engine, at equal work (same sequences).

Also the Table IV analogue (dispatch accounting, see DESIGN.md §4): frame
latency for the legacy per-phase engine vs the lane-persistent fused path
(``use_kernels=True``), which collapses the predict / IoU / update
dispatches and their layout round-trips into one ``fused_frame`` call per
frame on TPU.  Since PR 3 both engine rows run the same paper-exact
Hungarian association (DESIGN.md §6), so the comparison isolates layout
residency (+ launch overhead on TPU) — the association-algorithm axis
moved to ``benchmarks/association_ablation.py``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine
from repro.core.ref_numpy import Sort as RefSort
from repro.data.synthetic import SceneConfig, generate_scene


def run(num_streams: int = 64, num_frames: int = 120, seed: int = 0,
        repeats: int = 3, json_dir: str | None = None):
    scenes = [generate_scene(SceneConfig(num_frames=num_frames,
                                         max_objects=10, seed=seed + i))
              for i in range(num_streams)]
    d = max(s[2].shape[1] for s in scenes)
    det = np.zeros((num_frames, num_streams, d, 4), np.float32)
    msk = np.zeros((num_frames, num_streams, d), bool)
    for i, (_, _, db, dm) in enumerate(scenes):
        det[:, i, :db.shape[1]] = db
        msk[:, i, :dm.shape[1]] = dm

    # --- reference: per-stream, per-op numpy (original-Python shaped) ---
    n_ref_streams = min(num_streams, 8)  # don't wait forever
    t0 = time.perf_counter()
    for i in range(n_ref_streams):
        ref = RefSort()
        for t in range(num_frames):
            ref.update(det[t, i][msk[t, i]])
    t_ref = (time.perf_counter() - t0) / (n_ref_streams * num_frames)

    # --- ours: jitted batch, legacy per-phase vs lane-persistent fused ---
    db, dm = jnp.asarray(det), jnp.asarray(msk)

    def time_engine(use_kernels: bool) -> float:
        eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                    use_kernels=use_kernels))
        run_fn = jax.jit(eng.run)
        jax.block_until_ready(run_fn(eng.init(num_streams), db, dm))
        best = np.inf
        for _ in range(repeats):
            st = eng.init(num_streams)
            t0 = time.perf_counter()
            out = run_fn(st, db, dm)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best / (num_streams * num_frames)

    t_ours = time_engine(False)
    t_fused = time_engine(True)

    # Table IV analogue: per-frame kernel dispatches on the filter hot path.
    # Paper: ~15 BLAS calls per tracker update; per-phase Pallas kernels: 3
    # (predict, IoU, update) + layout round-trips; fused frame kernel: 1
    # (+ the jitted lane-batched JV stage feeding it — same device program,
    # DESIGN.md §6).  The dispatch counts describe the TPU execution;
    # off-TPU the fused path runs the same-math jnp oracle (one XLA
    # program either way), so there the row isolates layout residency,
    # not kernel-launch overhead — association is Hungarian on both rows.
    on_tpu = jax.default_backend() == "tpu"
    fused_note = ("dispatches/frame=1" if on_tpu
                  else "cpu-oracle (hungarian assoc, resident lane layout)")
    rows = [
        ("tableV/ref_python_us_per_frame", t_ref * 1e6,
         "dispatches/frame~15 tiny BLAS per tracker (paper Table IV)"),
        ("tableV/jax_batched_us_per_frame", t_ours * 1e6,
         f"speedup={t_ref / t_ours:.1f}x hungarian assoc"),
        ("tableV/jax_fused_lane_us_per_frame", t_fused * 1e6,
         f"speedup={t_ref / t_fused:.1f}x {fused_note} "
         f"(vs unfused {t_ours / t_fused:.2f}x)"),
        ("tableV/jax_batched_fps", 1.0 / t_ours,
         f"streams={num_streams}"),
        ("tableV/jax_fused_lane_fps", 1.0 / t_fused,
         f"streams={num_streams}"),
    ]
    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("speedup",
                    dict(num_streams=num_streams, num_frames=num_frames,
                         seed=seed, repeats=repeats,
                         backend=jax.default_backend()),
                    rows, json_dir)
    return rows
