"""Dispatch-overhead sweep: per-frame scan vs the chunk-resident megakernel.

The paper's Table IV complaint is per-op dispatch overhead around tiny
matrices; DESIGN.md §4 tracks how each PR collapsed it.  PR 6 moves the
*frame loop itself* inside ``pallas_call`` (DESIGN.md §9), so the number
that matters is **device dispatches per serving chunk**: the per-frame
path issues one fused kernel per frame (``F`` per chunk, via
``lax.scan``), the megakernel issues exactly one regardless of ``F``.

The dispatch counts here are *structural*, not sampled: we trace the
engine's ``run_chunk_ragged`` (``mode="interpret"`` so the Pallas path is
traced off-TPU too) and walk the jaxpr counting ``pallas_call`` equations,
multiplying through ``lax.scan`` trip counts.  Latency rows time the
``mode="auto"`` program at each chunk size; on TPU that is the real
kernel-vs-kernel comparison, off-TPU both rows run the same-math XLA
oracle so the latency delta collapses and the dispatch column is the
story.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine
from repro.data.synthetic import SceneConfig, generate_scene

CHUNK_SIZES = (1, 4, 16, 32, 64)


def _sub_jaxprs(params: dict):
    """Yield every jaxpr reachable from one equation's params."""
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v


def count_pallas_dispatches(jaxpr) -> int:
    """Count ``pallas_call`` equations reachable from ``jaxpr``, weighting
    sub-jaxprs under ``scan`` by the scan trip count (a kernel inside a
    ``lax.scan`` launches once per iteration)."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
            continue
        mult = eqn.params.get("length", 1) if eqn.primitive.name == "scan" else 1
        for sub in _sub_jaxprs(eqn.params):
            total += mult * count_pallas_dispatches(sub)
    return total


def chunk_dispatches(engine: SortEngine, det, dm, active, reset) -> int:
    """Structural dispatches-per-chunk for ``engine.run_chunk_ragged`` on
    the given planned chunk (traced with ``mode="interpret"`` so the
    Pallas program shape is counted even off-TPU)."""
    closed = jax.make_jaxpr(
        lambda st, d, m, a, r: engine.run_chunk_ragged(st, d, m, a, r,
                                                       mode="interpret")
    )(engine.init_ragged(active.shape[1]), det, dm, active, reset)
    return count_pallas_dispatches(closed.jaxpr)


def _planned_chunk(num_frames: int, num_lanes: int, seed: int):
    """A fully-occupied planned chunk: every lane active for all ``F``
    frames, recycled (reset) at frame 0 — the steady-state serving shape."""
    scenes = [generate_scene(SceneConfig(num_frames=num_frames,
                                         max_objects=6, seed=seed + i))
              for i in range(num_lanes)]
    d = max(s[2].shape[1] for s in scenes)
    det = np.zeros((num_frames, num_lanes, d, 4), np.float32)
    msk = np.zeros((num_frames, num_lanes, d), bool)
    for i, (_, _, db, dm) in enumerate(scenes):
        det[:, i, :db.shape[1]] = db
        msk[:, i, :dm.shape[1]] = dm
    active = np.ones((num_frames, num_lanes), bool)
    reset = np.zeros((num_frames, num_lanes), bool)
    reset[0, :] = True
    return (jnp.asarray(det), jnp.asarray(msk), jnp.asarray(active),
            jnp.asarray(reset), d)


def run(chunk_sizes=CHUNK_SIZES, num_lanes: int = 4, seed: int = 0,
        repeats: int = 3, json_dir: str | None = None):
    def engine(chunk_kernel: bool, d: int) -> SortEngine:
        return SortEngine(SortConfig(max_trackers=8, max_detections=d,
                                     use_kernels=True, assoc="greedy",
                                     chunk_kernel=chunk_kernel))

    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for f in chunk_sizes:
        det, dm, active, reset, d = _planned_chunk(f, num_lanes, seed)
        variants = [("scan", engine(False, d)), ("megakernel", engine(True, d))]
        timings, counts = {}, {}
        for label, eng in variants:
            counts[label] = chunk_dispatches(eng, det, dm, active, reset)
            run_fn = jax.jit(eng.run_chunk_ragged)
            st = eng.init_ragged(num_lanes)
            jax.block_until_ready(run_fn(st, det, dm, active, reset))
            best = np.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                out = run_fn(st, det, dm, active, reset)
                jax.block_until_ready(out)
                best = min(best, time.perf_counter() - t0)
            timings[label] = best / (f * num_lanes)
        note = "" if on_tpu else " (cpu-oracle timing)"
        rows.append((f"dispatch/scan_chunk{f}_us_per_frame",
                     timings["scan"] * 1e6,
                     f"dispatches_per_chunk={counts['scan']} per-frame lax.scan"
                     + note))
        rows.append((f"dispatch/megakernel_chunk{f}_us_per_frame",
                     timings["megakernel"] * 1e6,
                     f"dispatches_per_chunk={counts['megakernel']} "
                     f"dispatch_ratio={counts['scan'] / counts['megakernel']:.0f}x"
                     + note))

    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("dispatch_overhead",
                    dict(chunk_sizes=list(chunk_sizes), num_lanes=num_lanes,
                         seed=seed, repeats=repeats,
                         backend=jax.default_backend()),
                    rows, json_dir)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row_name, value, derived in run(json_dir="."):
        print(f"{row_name},{value:.4f},{derived}")
