"""Paper Table VI / Fig. 4 analogue: strong vs weak vs throughput scaling.

On TPU the paper's three modes map to (DESIGN.md §2):

* strong     — split one frame's tiny matrices across the ``model`` axis;
* weak       — one stream per worker (lane batch = #workers);
* throughput — many streams per worker (lane batch = k x #workers).

Two measurements:

1. **FPS vs lane count** on the host device: the vectorization win is the
   paper's throughput claim (each added lane is a paper "core").
2. **Structural collective cost** (subprocess, 8 fake devices): the SORT
   step lowered with stream-axis sharding (throughput/weak) vs tracker-axis
   sharding (strong); wire bytes per frame from the loop-aware HLO
   analysis.  Strong scaling pays collectives per tiny op; throughput pays
   none — the paper's conclusion, derived from the compiled artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine
from repro.data.synthetic import SceneConfig, generate_scene


def fps_vs_lanes(num_frames=60, lane_counts=(1, 4, 16, 64, 256), seed=0):
    scene = generate_scene(SceneConfig(num_frames=num_frames, max_objects=10,
                                       seed=seed))
    _, _, db, dm = scene
    d = db.shape[1]
    rows = []
    for s in lane_counts:
        eng = SortEngine(SortConfig(max_trackers=16, max_detections=d))
        det = jnp.asarray(np.repeat(db[:, None], s, 1))
        msk = jnp.asarray(np.repeat(dm[:, None], s, 1))
        run_fn = jax.jit(eng.run)
        jax.block_until_ready(run_fn(eng.init(s), det, msk))
        t0 = time.perf_counter()
        jax.block_until_ready(run_fn(eng.init(s), det, msk))
        dt = time.perf_counter() - t0
        rows.append((f"tableVI/throughput_fps_lanes={s}",
                     s * num_frames / dt, f"us_per_frame_per_lane="
                     f"{dt / (s * num_frames) * 1e6:.1f}"))
    return rows


_STRUCTURAL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import SortConfig, SortEngine
    from repro.launch.mesh import make_mesh
    from repro.launch.hlo_analysis import analyze_text

    mesh = make_mesh((4, 2), ("data", "model"))
    S, T, D = 64, 16, 16
    eng = SortEngine(SortConfig(max_trackers=T, max_detections=D))
    state = eng.init(S)
    det = jnp.zeros((S, D, 4)); msk = jnp.zeros((S, D), bool)

    def lower(state_spec, det_spec):
        st_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, state_spec(x)), state)
        _, out = jax.eval_shape(eng.step, state, det, msk)
        out_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, state_spec(x)), out)
        c = jax.jit(eng.step,
                    in_shardings=(st_sh,
                                  NamedSharding(mesh, det_spec),
                                  NamedSharding(mesh, P(*det_spec[:-1]))),
                    out_shardings=(st_sh, out_sh)
                    ).lower(state, det, msk).compile()
        return analyze_text(c.as_text())

    # throughput/weak: stream axis over data — paper's winning mode
    thr = lower(lambda x: P("data", *([None] * (x.ndim - 1))),
                P("data", None, None))
    # strong: tracker axis over model — paper's losing mode
    strong = lower(
        lambda x: P(None, "model", *([None] * max(x.ndim - 2, 0)))
        if x.ndim >= 2 else P(*([None] * x.ndim)),
        P(None, "model", None))

    # paper-faithful strong scaling: ONE stream's frame split over 8 chips
    # (vs. zero collectives for the same stream on one chip).
    mesh1 = make_mesh((1, 8), ("data", "model"))
    eng1 = SortEngine(SortConfig(max_trackers=T, max_detections=D))
    st1 = eng1.init(1)
    det1 = jnp.zeros((1, D, 4)); msk1 = jnp.zeros((1, D), bool)
    def spec1(x):
        return P(None, "model", *([None] * max(x.ndim - 2, 0))) \
            if x.ndim >= 2 else P(*([None] * x.ndim))
    st_sh1 = jax.tree.map(lambda x: NamedSharding(mesh1, spec1(x)), st1)
    _, out1 = jax.eval_shape(eng1.step, st1, det1, msk1)
    out_sh1 = jax.tree.map(lambda x: NamedSharding(mesh1, spec1(x)), out1)
    c1 = jax.jit(eng1.step,
                 in_shardings=(st_sh1, NamedSharding(mesh1, P(None, "model", None)),
                               NamedSharding(mesh1, P(None, "model"))),
                 out_shardings=(st_sh1, out_sh1)).lower(st1, det1, msk1).compile()
    strong1 = analyze_text(c1.as_text())

    print(json.dumps({
        "throughput_coll_bytes": thr["collective_bytes"],
        "strong_coll_bytes": strong["collective_bytes"],
        "strong1_coll_bytes_per_stream_frame": strong1["collective_bytes"],
        "throughput_coll_bytes_per_stream_frame": thr["collective_bytes"] / S,
        "throughput_flops": thr["flops"], "strong_flops": strong["flops"],
    }))
""")


def structural():
    r = subprocess.run(
        [sys.executable, "-c", _STRUCTURAL], capture_output=True, text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": ""})
    if r.returncode != 0:
        return [("tableVI/structural_error", -1.0, r.stderr[-200:])]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    ratio = (out["strong1_coll_bytes_per_stream_frame"]
             / max(out["throughput_coll_bytes_per_stream_frame"], 1))
    return [
        ("tableVI/throughput_sharding_coll_bytes_per_step",
         out["throughput_coll_bytes"], "streams-over-data, 64 streams"),
        ("tableVI/strong_sharding_coll_bytes_per_step",
         out["strong_coll_bytes"], "trackers-over-model, 64 streams"),
        ("tableVI/strong1_coll_bytes_per_stream_frame",
         out["strong1_coll_bytes_per_stream_frame"],
         f"ONE stream split over 8 chips: {ratio:.0f}x the wire bytes per "
         f"stream-frame of throughput mode"),
    ]


def run():
    return fps_vs_lanes() + structural()
