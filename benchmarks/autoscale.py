"""Bursty-traffic throughput: elastic lane budget vs fixed budgets.

The ragged benchmark (``benchmarks/ragged.py``) shows lane recycling
beating pad-to-max at a *fixed* lane budget.  This benchmark attacks the
budget itself (DESIGN.md §8): real arrival traces are bursty, so a fixed
budget either starves bursts (``min`` lanes: admissions queue behind too
few lanes) or drags idle width through the quiet phases (``max`` lanes:
every dispatched step pays ``max`` lanes of kernel width for a handful of
live sequences — the right-sizing lever the edge-tracking measurement
study in PAPERS.md identifies as dominant).

The trace is a 4-phase arrival pattern — quiet, burst, quiet, burst —
served three ways at identical chunking:

* **fixed-min** — ``num_lanes = min_lanes`` (provisioned for the quiet
  phase; bursts serialize);
* **fixed-max** — ``num_lanes = max_lanes`` (provisioned for the burst;
  quiet phases run mostly-idle lanes);
* **elastic** — ``min_lanes..max_lanes`` ladder: grows the moment a
  burst's queue depth exceeds the width, shrinks back once the burst's
  lanes drain.  Outputs are bit-identical to fixed-max
  (``tests/test_autoscale.py``); only the dispatched width changes.

Reported per variant: wall-clock throughput over real frames and lane
utilization of the dispatched steps; the elastic row adds the resize
trail and mean dispatched width.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SortConfig, SortEngine
from repro.data.synthetic import SceneConfig, generate_scene
from repro.serve import StreamScheduler


def _phases(light: int, heavy: int, frames: int, seed: int):
    """4-phase arrival trace: [light, heavy, light, heavy] sequence
    counts, each sequence ``frames`` long (uniform length isolates the
    budget effect from the raggedness effect ragged.py measures)."""
    out = []
    k = 0
    for n in (light, heavy, light, heavy):
        phase = []
        for _ in range(n):
            _, _, db, dm = generate_scene(SceneConfig(
                num_frames=frames, max_objects=8, seed=seed + k))
            phase.append((f"seq{k}", db, dm))
            k += 1
        out.append(phase)
    return out


def _pad_dets(phases):
    d = max(db.shape[1] for ph in phases for _, db, _ in ph)
    return [[(n, np.pad(db, ((0, 0), (0, d - db.shape[1]), (0, 0))),
              np.pad(dm, ((0, 0), (0, d - dm.shape[1]))))
             for n, db, dm in ph] for ph in phases], d


def _serve_trace(sched, phases) -> float:
    """Replay the trace: each phase's sequences arrive together and the
    scheduler drains before the next phase (the inter-phase idle gap)."""
    t0 = time.perf_counter()
    done = 0
    for phase in phases:
        for name, db, dm in phase:
            sched.submit(name, db, dm)
        done += len(sched.run())
    assert done == sum(len(p) for p in phases)
    return time.perf_counter() - t0


def _mean_width(sched) -> float:
    """Mean dispatched lane width over the run, from the resize trail."""
    if sched.chunks_run == 0:
        return float(sched.num_lanes)
    events = iter(sched.resizes + [(sched.chunks_run, sched.num_lanes,
                                    sched.num_lanes)])
    nxt = next(events)
    width = nxt[1] if sched.resizes else sched.num_lanes
    total = 0
    for c in range(sched.chunks_run):
        while c >= nxt[0]:
            width = nxt[2]
            nxt = next(events, (sched.chunks_run + 1, width, width))
        total += width
    return total / sched.chunks_run


def run(light: int = 2, heavy: int = 12, frames: int = 60,
        min_lanes: int = 2, max_lanes: int = 8, chunk: int = 8,
        seed: int = 0, repeats: int = 2, use_kernels: bool = True,
        json_dir: str | None = None):
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1 (rep 0 only warms the "
                         f"jit and is never timed), got {repeats}")
    phases, d = _pad_dets(_phases(light, heavy, frames, seed))
    real_frames = sum(len(p) for p in phases) * frames
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                use_kernels=use_kernels))

    def best_of(make_sched):
        """Best timed replay as (dt, utilization, resizes, mean width) —
        one snapshot, so every number in a row describes the SAME
        execution (reps can differ: the elastic scheduler starts each
        replay at the width the previous one ended at)."""
        sched = make_sched()
        best = None
        for rep in range(repeats + 1):         # first rep warms the jit
            # zero the accounting each replay so the stats describe ONE
            # timed replay of the trace, not the warm-up rep summed in
            sched.frames_processed = sched.lane_steps = sched.chunks_run = 0
            sched.admissions.clear()
            sched.resizes.clear()
            dt = _serve_trace(sched, phases)
            if rep > 0 and (best is None or dt < best[0]):
                best = (dt, sched.utilization, len(sched.resizes),
                        _mean_width(sched))
        return best

    t_min, u_min, _, _ = best_of(lambda: StreamScheduler(
        eng, num_lanes=min_lanes, max_dets=d, chunk=chunk))
    t_max, u_max, _, _ = best_of(lambda: StreamScheduler(
        eng, num_lanes=max_lanes, max_dets=d, chunk=chunk))
    t_el, u_el, n_resizes, mean_w = best_of(lambda: StreamScheduler(
        eng, max_dets=d, chunk=chunk,
        min_lanes=min_lanes, max_lanes=max_lanes))

    fps = {k: real_frames / t for k, t in
           (("min", t_min), ("max", t_max), ("el", t_el))}
    rows = [
        ("autoscale/fixed_min_us_per_frame", t_min / real_frames * 1e6,
         f"fps={fps['min']:,.0f} lanes={min_lanes} util={u_min:.0%}"),
        ("autoscale/fixed_max_us_per_frame", t_max / real_frames * 1e6,
         f"fps={fps['max']:,.0f} lanes={max_lanes} util={u_max:.0%}"),
        ("autoscale/elastic_us_per_frame", t_el / real_frames * 1e6,
         f"fps={fps['el']:,.0f} ladder={min_lanes}-{max_lanes} "
         f"util={u_el:.0%} resizes={n_resizes} "
         f"mean_width={mean_w:.1f}"),
        ("autoscale/elastic_vs_fixed_min", fps["el"] / fps["min"],
         f"burst speedup at {heavy} arrivals over {min_lanes} lanes"),
        ("autoscale/elastic_vs_fixed_max", u_el / max(u_max, 1e-9),
         "lane-utilization ratio (elastic right-sizes the quiet phases)"),
    ]
    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("autoscale",
                    dict(light=light, heavy=heavy, frames=frames,
                         min_lanes=min_lanes, max_lanes=max_lanes,
                         chunk=chunk, seed=seed, repeats=repeats,
                         use_kernels=use_kernels),
                    rows, json_dir)
    return rows


if __name__ == "__main__":
    for name, value, derived in run(json_dir="."):
        print(f"{name},{value:.4f},{derived}")
