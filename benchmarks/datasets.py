"""Paper Table I analogue: the 11 MOT15-shaped sequences, tracked at once.

Synthetic stand-ins replicate each sequence's frame count and max object
count (motchallenge data is not redistributable); all 11 are packed into
one lane batch — the paper's 11-files-11-cores weak scaling becomes
11 lanes of one device step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SortConfig, SortEngine, metrics
from repro.data import stream
from repro.data.mot import TABLE_I
from repro.data.synthetic import SceneConfig, generate_scene


def run(seed=0):
    seqs, gts = [], {}
    for i, (name, (frames, max_obj)) in enumerate(TABLE_I.items()):
        cfg = SceneConfig(num_frames=frames, max_objects=max_obj,
                          seed=seed + i)
        gt_boxes, gt_mask, db, dm = generate_scene(cfg)
        seqs.append((name, db, dm))
        gts[name] = (gt_boxes, gt_mask)
    batch = stream.pack(seqs, pad_multiple=1)
    f, s, d, _ = batch.det_boxes.shape
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d))
    run_fn = jax.jit(eng.run)
    db = jnp.asarray(batch.det_boxes)
    dm = jnp.asarray(batch.det_mask)
    jax.block_until_ready(run_fn(eng.init(s), db, dm))
    t0 = time.perf_counter()
    _, out = run_fn(eng.init(s), db, dm)
    jax.block_until_ready(out.boxes)
    dt = time.perf_counter() - t0

    total_frames = sum(fr for fr, _ in TABLE_I.values())
    rows = [("tableI/total_fps", total_frames / dt,
             f"11 sequences, {total_frames} frames (paper: 5500)")]
    for i, name in enumerate(TABLE_I):
        fr = TABLE_I[name][0]
        gt_boxes, gt_mask = gts[name]
        m = metrics.mota(gt_boxes, gt_mask,
                         np.asarray(out.boxes[:fr, i]),
                         np.asarray(out.uid[:fr, i]),
                         np.asarray(out.emit[:fr, i]))
        rows.append((f"tableI/{name}_mota", m["mota"],
                     f"frames={fr} idsw={m['id_switches']}"))
    return rows
