"""Serving throughput vs device count — the lane axis over a JAX mesh.

The paper's Table VI scales throughput by adding OpenMP workers, one video
per worker; DESIGN.md §7 takes the same model across *devices*: the
scheduler's lane budget is sharded contiguously over a 1-D ``("lanes",)``
mesh, each device scanning its own lane shard with zero collectives.
This benchmark serves one fixed ragged traffic mix through the same lane
budget at increasing device counts and reports real-frames-per-second —
the device-scaling analogue of ``benchmarks/scaling.py``'s thread sweep.

On CPU the devices are simulated host devices; run standalone (the
``__main__`` block forces 8 of them before jax initializes)::

    PYTHONPATH=src python benchmarks/device_scaling.py

or under the suite driver with the flag exported::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.run

What the rows mean by backend:

* **CPU (simulated devices)** — the shards share the host's cores, so
  expect <= 1x vs unsharded: the rows measure the sharded program's
  dispatch/placement *overhead*, not scaling.  The value of the sweep is
  that the harness, placement, and bit-identical outputs are exercised on
  every shard count that CI can reach.
* **TPU (real chips)** — scaling requires each shard to carry enough
  lanes to fill its kernel grid: the fused path pads every device's
  stream count up to ``block_s = block_b // max_trackers`` (128 by
  default), so size ``num_lanes >= block_s * devices`` or the padded
  blocks dominate and adding devices multiplies wasted compute instead
  of throughput.  The CPU default (``num_lanes=8``) is NOT that regime —
  CPU pads nothing (``SortEngine._block_s == 1``); rescale the knobs when
  pointing this at hardware.
"""
from __future__ import annotations

import time

import numpy as np


def _traffic(num_seqs: int, long_frames: int, skew: int, seed: int):
    """Arrival-interleaved ragged mix, same shape as benchmarks/ragged.py."""
    from repro.data.synthetic import SceneConfig, generate_scene

    seqs = []
    for i in range(num_seqs):
        f = long_frames if i % 2 == 0 else max(1, long_frames // skew)
        _, _, db, dm = generate_scene(
            SceneConfig(num_frames=f, max_objects=8, seed=seed + i))
        seqs.append((f"seq{i}", db, dm))
    d = max(s[1].shape[1] for s in seqs)
    padded = []
    for name, db, dm in seqs:
        grow = d - db.shape[1]
        padded.append((name, np.pad(db, ((0, 0), (0, grow), (0, 0))),
                       np.pad(dm, ((0, 0), (0, grow)))))
    return padded, d


def run(num_seqs: int = 16, long_frames: int = 96, skew: int = 4,
        num_lanes: int = 8, chunk: int = 16, seed: int = 0,
        repeats: int = 2, use_kernels: bool = True,
        device_counts: tuple = (1, 2, 4, 8), json_dir: str | None = None):
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    # jax deferred so the __main__ block can force host devices first
    from repro.core import SortConfig, SortEngine
    from repro.serve import StreamScheduler
    from repro.sharding import lane_mesh

    import jax

    avail = jax.device_count()
    counts = [c for c in device_counts if c <= avail and num_lanes % c == 0]
    dropped = [c for c in device_counts if c not in counts]

    seqs, d = _traffic(num_seqs, long_frames, skew, seed)
    real_frames = sum(s[1].shape[0] for s in seqs)
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                use_kernels=use_kernels))

    def time_serve(mesh) -> float:
        sched = StreamScheduler(eng, num_lanes=num_lanes, max_dets=d,
                                chunk=chunk, mesh=mesh)
        best = np.inf
        for rep in range(repeats + 1):       # first rep warms the jit
            t0 = time.perf_counter()
            for name, db, dm in seqs:
                sched.submit(name, db, dm)
            n_done = len(sched.run())
            dt = time.perf_counter() - t0
            assert n_done == num_seqs
            if rep > 0:
                best = min(best, dt)
        return best

    rows = []
    t_base = time_serve(None)
    rows.append(("devices/unsharded_us_per_frame",
                 t_base / real_frames * 1e6,
                 f"fps={real_frames / t_base:,.0f} lanes={num_lanes} "
                 f"chunk={chunk} (no mesh)"))
    for n in counts:
        t = time_serve(lane_mesh(n))
        rows.append((f"devices/throughput_{n}dev_us_per_frame",
                     t / real_frames * 1e6,
                     f"fps={real_frames / t:,.0f} "
                     f"vs_unsharded={t_base / t:.2f}x "
                     f"lanes_per_device={num_lanes // n}"))
    if dropped:
        rows.append(("devices/unmeasured_counts", float(len(dropped)),
                     f"device counts {dropped} skipped: "
                     f"jax.device_count()={avail}, num_lanes={num_lanes} "
                     f"(set XLA_FLAGS=--xla_force_host_platform_device_"
                     f"count={max(device_counts)} before jax initializes)"))
    if json_dir is not None:
        from benchmarks._record import write_bench
        write_bench("device_scaling",
                    dict(num_seqs=num_seqs, long_frames=long_frames,
                         skew=skew, num_lanes=num_lanes, chunk=chunk,
                         seed=seed, repeats=repeats,
                         use_kernels=use_kernels,
                         device_counts=list(device_counts),
                         measured_counts=counts,
                         backend=jax.default_backend()),
                    rows, json_dir)
    return rows


if __name__ == "__main__":
    import os
    import sys

    if "jax" not in sys.modules:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    for name, value, derived in run(json_dir="."):
        print(f"{name},{value:.4f},{derived}")
