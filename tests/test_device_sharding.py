"""Device-sharded lane serving: bit-parity with single-device (DESIGN.md §7).

The load-bearing invariant: sharding the lane axis over a ``("lanes",)``
mesh changes *where* each lane's math runs and nothing else — a sharded
run is bit-identical to the single-device run on both engine paths and
both association modes, including mid-chunk lane recycling, and the
compiled chunk program contains zero cross-device collectives.

The multi-device cases need simulated devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_device_sharding.py

(the CI ``multi-device`` job runs exactly that); under a plain
single-device session they skip, and the mesh-of-one cases still run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import SortConfig, SortEngine, cost as cost_mod
from repro.data.synthetic import (SceneConfig, generate_multiclass_scene,
                                  generate_scene)
from repro.serve import StreamScheduler
from repro.sharding import LaneSharding, lane_mesh, state_pspecs
from repro.sharding.lanes import lane_view, mesh_view

NDEV = jax.device_count()
needs_multi = pytest.mark.skipif(
    NDEV < 4, reason="needs >=4 devices: run with XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8")

MAX_DETS = 7
LENGTHS = [12, 5, 9, 5, 1, 7]   # ragged mix, forces mid-chunk recycling


def _scene(seed, frames):
    _, _, db, dm = generate_scene(
        SceneConfig(num_frames=frames, max_objects=4, seed=seed))
    d = db.shape[1]
    assert d <= MAX_DETS, d
    return (np.pad(db, ((0, 0), (0, MAX_DETS - d), (0, 0))),
            np.pad(dm, ((0, 0), (0, MAX_DETS - d))))


def _engine(use_kernels, assoc="hungarian", chunk_kernel=False):
    return SortEngine(SortConfig(max_trackers=8, max_detections=MAX_DETS,
                                 use_kernels=use_kernels, assoc=assoc,
                                 chunk_kernel=chunk_kernel))


def _serve(eng, seqs, mesh, num_lanes=4, chunk=4):
    sched = StreamScheduler(eng, num_lanes=num_lanes, chunk=chunk, mesh=mesh)
    for name, db, dm in seqs:
        sched.submit(name, db, dm)
    return sched, sched.run()


def _assert_results_equal(a, b):
    assert [r.name for r in a] == [r.name for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.uid, rb.uid, err_msg=ra.name)
        np.testing.assert_array_equal(ra.emit, rb.emit, err_msg=ra.name)
        np.testing.assert_array_equal(ra.boxes, rb.boxes, err_msg=ra.name)


# ------------------------------------------------------------- bit parity
@needs_multi
@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("assoc", ["hungarian", "greedy"])
def test_sharded_bit_identical_to_single_device(use_kernels, assoc):
    """2x2 grid (engine path x association mode): a ragged mix served over
    a 4-device lane mesh equals the unsharded run bit for bit."""
    seqs = [(f"s{i}", *_scene(i, f)) for i, f in enumerate(LENGTHS)]
    eng = _engine(use_kernels, assoc)
    _, solo = _serve(eng, seqs, mesh=None)
    _, shard = _serve(eng, seqs, mesh=lane_mesh(4))
    _assert_results_equal(solo, shard)


def test_mesh_of_one_matches_unsharded():
    """The sharding layer with a single-device mesh is the identity —
    runs in any session, keeping the shard_map path exercised even where
    simulated devices are unavailable."""
    seqs = [(f"m{i}", *_scene(40 + i, f)) for i, f in enumerate([6, 3, 8])]
    eng = _engine(True)
    _, solo = _serve(eng, seqs, mesh=None, num_lanes=2)
    _, shard = _serve(eng, seqs, mesh=lane_mesh(1), num_lanes=2)
    _assert_results_equal(solo, shard)


@needs_multi
def test_sharded_drain_and_zero_frame_sequences():
    """The drain/lifecycle surface behaves identically in mesh mode:
    zero-frame sequences surface via pop_ready without a dispatch."""
    sched = StreamScheduler(_engine(True), num_lanes=4, chunk=4,
                            mesh=lane_mesh(4))
    sched.submit("empty", np.zeros((0, MAX_DETS, 4), np.float32),
                 np.zeros((0, MAX_DETS), bool))
    assert sched.busy
    assert [t.name for t in sched.pop_ready()] == ["empty"]
    assert sched.chunks_run == 0 and not sched.busy


# ----------------------------------------------- chunk-resident megakernel
@needs_multi
@pytest.mark.parametrize("assoc", ["hungarian", "greedy"])
def test_sharded_megakernel_bit_identical_to_single_device(assoc):
    """The chunk-resident dispatch mode (DESIGN.md §9) composes with the
    lane mesh: the same ragged mix served by the megakernel over 4
    devices equals the unsharded per-frame-scan run bit for bit."""
    seqs = [(f"k{i}", *_scene(20 + i, f)) for i, f in enumerate(LENGTHS)]
    _, solo = _serve(_engine(True, assoc), seqs, mesh=None)
    _, shard = _serve(_engine(True, assoc, chunk_kernel=True), seqs,
                      mesh=lane_mesh(4))
    _assert_results_equal(solo, shard)


@pytest.mark.parametrize("assoc", ["hungarian", "greedy"])
def test_megakernel_mesh_of_one_matches_unsharded(assoc):
    """Mesh-of-one megakernel (shard_map wrapping the chunk dispatch) is
    the identity — runs in any session."""
    seqs = [(f"ko{i}", *_scene(30 + i, f)) for i, f in enumerate([6, 3, 8])]
    _, solo = _serve(_engine(True, assoc), seqs, mesh=None, num_lanes=2)
    _, shard = _serve(_engine(True, assoc, chunk_kernel=True), seqs,
                      mesh=lane_mesh(1), num_lanes=2)
    _assert_results_equal(solo, shard)


# --------------------------------------- multiclass operands (DESIGN.md §10)
MC_EMBED = 4


def _mc_scene(seed, frames):
    _, _, _, db, dm, dc, de = generate_multiclass_scene(
        SceneConfig(num_frames=frames, max_objects=4, seed=seed),
        num_classes=3, embed_dim=MC_EMBED)
    d = db.shape[1]
    assert d <= MAX_DETS, d
    pad = MAX_DETS - d
    return (np.pad(db, ((0, 0), (0, pad), (0, 0))),
            np.pad(dm, ((0, 0), (0, pad))),
            np.pad(dc, ((0, 0), (0, pad))),
            np.pad(de, ((0, 0), (0, pad), (0, 0))))


def _mc_engine(chunk_kernel=False):
    return SortEngine(SortConfig(max_trackers=8, max_detections=MAX_DETS,
                                 use_kernels=True, chunk_kernel=chunk_kernel,
                                 cost=cost_mod.iou_embed(MC_EMBED),
                                 num_classes=3))


def _serve_mc(eng, seqs, mesh, num_lanes=4, chunk=4):
    sched = StreamScheduler(eng, num_lanes=num_lanes, chunk=chunk, mesh=mesh)
    for name, db, dm, dc, de in seqs:
        sched.submit(name, db, dm, det_class=dc, det_embed=de)
    return sched, sched.run()


def _assert_mc_results_equal(a, b):
    _assert_results_equal(a, b)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.cls, rb.cls, err_msg=ra.name)


@needs_multi
@pytest.mark.parametrize("chunk_kernel", [False, True])
def test_sharded_multiclass_bit_identical_to_single_device(chunk_kernel):
    """The det_class/det_embed extras and the track-class output ride the
    same lane partitioning as every other chunk operand: a multiclass
    composed-cost mix (iou+embed, 3 classes) served over a 4-device mesh —
    including the embedding leaf in the resident state — equals the
    unsharded run bit for bit, classes included, under both dispatch
    modes."""
    seqs = [(f"mc{i}", *_mc_scene(50 + i, f)) for i, f in enumerate(LENGTHS)]
    _, solo = _serve_mc(_mc_engine(), seqs, mesh=None)
    _, shard = _serve_mc(_mc_engine(chunk_kernel=chunk_kernel), seqs,
                         mesh=lane_mesh(4))
    _assert_mc_results_equal(solo, shard)


def test_multiclass_mesh_of_one_matches_unsharded():
    """Mesh-of-one multiclass serving (extras + cls through shard_map) is
    the identity — runs in any session."""
    seqs = [(f"mo{i}", *_mc_scene(70 + i, f)) for i, f in enumerate([6, 3, 8])]
    _, solo = _serve_mc(_mc_engine(), seqs, mesh=None, num_lanes=2)
    _, shard = _serve_mc(_mc_engine(), seqs, mesh=lane_mesh(1), num_lanes=2)
    _assert_mc_results_equal(solo, shard)


@needs_multi
def test_sharded_multiclass_chunk_program_has_no_collectives():
    """Zero-collective claim survives the extra operands: the lowered
    multiclass chunk program (class + embed inputs, cls output) contains
    no cross-device collectives."""
    c, lanes, d = 3, 4, MAX_DETS
    sched = StreamScheduler(_mc_engine(), num_lanes=lanes, chunk=c,
                            mesh=lane_mesh(4))
    det = np.zeros((c, lanes, d, 4), np.float32)
    dm = np.zeros((c, lanes, d), bool)
    active = np.ones((c, lanes), bool)
    reset = np.zeros((c, lanes), bool)
    extras = sched._zero_extras(c, lanes, d)
    lowered = sched._chunk_fn.lower(
        sched._state,
        *sched._sharding.place(det, dm, active, reset, *extras))
    text = lowered.as_text()
    for op in ("all_reduce", "all_gather", "all_to_all",
               "collective_permute", "psum", "ppermute"):
        assert op not in text, f"collective {op} in multiclass chunk program"


# ---------------------------------------------------------- mesh plumbing
@needs_multi
def test_lane_budget_must_divide_shard_count():
    with pytest.raises(ValueError, match="divide"):
        StreamScheduler(_engine(True), num_lanes=3, mesh=lane_mesh(2))


def test_lane_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="device_count"):
        lane_mesh(NDEV + 1)


def test_mesh_lane_state_views_are_exact_inverses():
    eng = _engine(True)
    lane = eng.init_ragged(6)
    back = lane_view(mesh_view(lane))
    for a, b in zip(jax.tree.leaves(lane), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_multi
def test_state_stays_lane_sharded_across_chunks():
    """The resident state never collapses to a replicated/single-device
    layout between chunks — every leaf keeps a 'lanes' NamedSharding, so
    no chunk pays a resharding copy."""
    from jax.sharding import NamedSharding, PartitionSpec

    seqs = [(f"r{i}", *_scene(60 + i, f)) for i, f in enumerate([9, 4, 7])]
    eng = _engine(True)
    sched, _ = _serve(eng, seqs, mesh=lane_mesh(4))
    specs = state_pspecs(sched._state)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    for leaf, spec in zip(jax.tree.leaves(sched._state), spec_leaves):
        assert isinstance(leaf.sharding, NamedSharding), leaf.shape
        assert leaf.sharding.spec == spec, (leaf.shape, leaf.sharding.spec)


@needs_multi
@pytest.mark.parametrize("use_kernels,chunk_kernel",
                         [(False, False), (True, False), (True, True)])
def test_sharded_chunk_program_has_no_collectives(use_kernels, chunk_kernel):
    """Sequences are independent, so the sharded chunk must lower to N
    disjoint per-device scans: no collective op of any kind may appear in
    the lowered program (the zero-collectives claim, checked not asserted
    from prose) — including the megakernel dispatch mode."""
    c, lanes, d = 3, 4, MAX_DETS
    sched = StreamScheduler(_engine(use_kernels, chunk_kernel=chunk_kernel),
                            num_lanes=lanes, chunk=c, mesh=lane_mesh(4))
    det = np.zeros((c, lanes, d, 4), np.float32)
    dm = np.zeros((c, lanes, d), bool)
    active = np.ones((c, lanes), bool)
    reset = np.zeros((c, lanes), bool)
    lowered = sched._chunk_fn.lower(
        sched._state, *sched._sharding.place(det, dm, active, reset))
    text = lowered.as_text()
    for op in ("all_reduce", "all_gather", "all_to_all",
               "collective_permute", "psum", "ppermute"):
        assert op not in text, f"collective {op} in sharded chunk program"


# ------------------------------------------------------- property coverage
@pytest.mark.slow
@needs_multi
@settings(max_examples=4, deadline=None, derandomize=True)
@given(lengths=st.lists(st.sampled_from([1, 4, 9, 12]), min_size=1,
                        max_size=6),
       shards=st.sampled_from([2, 4]))
def test_sharded_exactness_property(lengths, shards):
    """Any ragged mix over any shard count stays bit-identical to the
    unsharded run (fused Hungarian path; recycling churn included)."""
    seqs = [(f"p{i}", *_scene(80 + i, f)) for i, f in enumerate(lengths)]
    eng = _engine(True)
    _, solo = _serve(eng, seqs, mesh=None)
    _, shard = _serve(eng, seqs, mesh=lane_mesh(shards))
    _assert_results_equal(solo, shard)


# ------------------------------------- checkpoint topology neutrality §11
@needs_multi
def test_export_import_across_topologies():
    """The serving checkpoint is topology-neutral (DESIGN.md §11): state
    exported from a 4-device mesh resumes bit-exactly on a single device
    and vice versa — the engine-layout crossing erases the placement."""
    seqs = [(f"s{i}", *_scene(40 + i, f)) for i, f in enumerate(LENGTHS)]
    eng = _engine(True)
    _, ref = _serve(eng, seqs, mesh=lane_mesh(4))

    def interrupted(save_mesh, load_mesh):
        a = StreamScheduler(_engine(True), num_lanes=4, chunk=4,
                            mesh=save_mesh)
        for name, db, dm in seqs:
            a.submit(name, db, dm)
        out = a.run_chunk()
        meta, arrays = a.export_state()
        b = StreamScheduler(_engine(True), num_lanes=4, chunk=4,
                            mesh=load_mesh)
        b.import_state(meta, arrays)
        while b.busy:
            out.extend(b.run_chunk())
        return out

    _assert_results_equal(interrupted(lane_mesh(4), None), ref)
    _assert_results_equal(interrupted(None, lane_mesh(4)), ref)
