"""End-to-end oracle parity: numpy reference SORT == batched engine.

Runs whole synthetic sequences through ``core.ref_numpy.Sort`` (the
faithful per-stream scipy-backed port of the original implementation the
paper profiles) and through ``SortEngine`` on **both** execution paths
under **both** association modes (DESIGN.md §6):

* ``use_kernels=False`` x ``assoc in {"hungarian", "greedy"}``
* ``use_kernels=True``  x ``assoc in {"hungarian", "greedy"}`` — the
  fused lane path; with ``"hungarian"`` its JV solve runs as the
  lane-batched stage feeding the single fused dispatch, and this test is
  the fused-Hungarian vs scipy ``linear_sum_assignment`` lockdown.

Track identities must match exactly; boxes match to float32-vs-float64
tolerance.  Hypothesis drives scene seeds and object densities; the
engines are cached per (shape, path, assoc) so examples reuse
compilations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import SortConfig, SortEngine
from repro.core.ref_numpy import Sort as RefSort
from repro.data.synthetic import SceneConfig, generate_scene

NUM_FRAMES = 45  # fixed so every hypothesis example reuses the jit cache
PATHS = [(False, "hungarian"), (False, "greedy"),
         (True, "hungarian"), (True, "greedy")]
_ENGINES: dict = {}


def _scene(seed, max_objects):
    _, _, db, dm = generate_scene(SceneConfig(
        num_frames=NUM_FRAMES, max_objects=max_objects, seed=seed))
    return db, dm


def _run_engine(db, dm, use_kernels, assoc):
    key = (db.shape[1], use_kernels, assoc)
    if key not in _ENGINES:
        eng = SortEngine(SortConfig(max_trackers=16,
                                    max_detections=db.shape[1],
                                    use_kernels=use_kernels, assoc=assoc))
        _ENGINES[key] = (eng, jax.jit(eng.run))
    eng, run_fn = _ENGINES[key]
    _, out = run_fn(eng.init(1), jnp.asarray(db)[:, None],
                    jnp.asarray(dm)[:, None])
    return out


def _run_ref(db, dm, assoc):
    ref = RefSort(assoc=assoc)
    return [ref.update(db[t][dm[t]]) for t in range(db.shape[0])]


def _assert_identical_streams(out, ref_frames, ctx=""):
    for t, ref_t in enumerate(ref_frames):
        em = np.asarray(out.emit[t, 0])
        uids = np.asarray(out.uid[t, 0])
        ids_ours = sorted(int(u) for u in uids[em])
        ids_ref = sorted(int(o[4]) for o in ref_t)
        assert ids_ours == ids_ref, f"frame {t} {ctx}"
        boxes_ours = {int(u): np.asarray(out.boxes[t, 0, k])
                      for k, u in enumerate(uids) if em[k]}
        for o in ref_t:
            np.testing.assert_allclose(boxes_ours[int(o[4])], o[:4],
                                       rtol=1e-3, atol=0.5,
                                       err_msg=f"frame {t} uid {o[4]} {ctx}")


@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@pytest.mark.parametrize("seed,max_objects", [(0, 4), (13, 6)])
def test_oracle_parity_deterministic(use_kernels, assoc, seed, max_objects):
    db, dm = _scene(seed, max_objects)
    out = _run_engine(db, dm, use_kernels, assoc)
    ref_frames = _run_ref(db, dm, assoc)
    _assert_identical_streams(out, ref_frames,
                              f"(uk={use_kernels} assoc={assoc} seed={seed})")


@pytest.mark.slow
@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@settings(max_examples=8, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**31 - 1), max_objects=st.sampled_from([4, 6]))
def test_oracle_parity_property(use_kernels, assoc, seed, max_objects):
    """Hypothesis sweep over scene seeds and object densities: the batched
    engine (every path x assoc combination) and the per-stream numpy
    oracle emit identical track streams."""
    db, dm = _scene(seed, max_objects)
    out = _run_engine(db, dm, use_kernels, assoc)
    ref_frames = _run_ref(db, dm, assoc)
    _assert_identical_streams(out, ref_frames,
                              f"(uk={use_kernels} assoc={assoc} seed={seed})")
