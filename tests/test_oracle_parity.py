"""End-to-end oracle parity: numpy reference SORT == batched engine.

Runs whole synthetic sequences through ``core.ref_numpy.Sort`` (the
faithful per-stream scipy-backed port of the original implementation the
paper profiles) and through ``SortEngine`` on **both** execution paths
under **both** association modes (DESIGN.md §6):

* ``use_kernels=False`` x ``assoc in {"hungarian", "greedy"}``
* ``use_kernels=True``  x ``assoc in {"hungarian", "greedy"}`` — the
  fused lane path; with ``"hungarian"`` its JV solve runs as the
  lane-batched stage feeding the single fused dispatch, and this test is
  the fused-Hungarian vs scipy ``linear_sum_assignment`` lockdown.

Track identities must match exactly; boxes match to float32-vs-float64
tolerance.  Hypothesis drives scene seeds and object densities; the
engines are cached per (shape, path, assoc) so examples reuse
compilations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import SortConfig, SortEngine, cost as cost_mod
from repro.core.ref_numpy import Sort as RefSort
from repro.data.synthetic import (SceneConfig, generate_crossing_scene,
                                  generate_multiclass_scene, generate_scene)

NUM_FRAMES = 45  # fixed so every hypothesis example reuses the jit cache
PATHS = [(False, "hungarian"), (False, "greedy"),
         (True, "hungarian"), (True, "greedy")]
_ENGINES: dict = {}


def _scene(seed, max_objects):
    _, _, db, dm = generate_scene(SceneConfig(
        num_frames=NUM_FRAMES, max_objects=max_objects, seed=seed))
    return db, dm


def _run_engine(db, dm, use_kernels, assoc):
    key = (db.shape[1], use_kernels, assoc)
    if key not in _ENGINES:
        eng = SortEngine(SortConfig(max_trackers=16,
                                    max_detections=db.shape[1],
                                    use_kernels=use_kernels, assoc=assoc))
        _ENGINES[key] = (eng, jax.jit(eng.run))
    eng, run_fn = _ENGINES[key]
    _, out = run_fn(eng.init(1), jnp.asarray(db)[:, None],
                    jnp.asarray(dm)[:, None])
    return out


def _run_ref(db, dm, assoc):
    ref = RefSort(assoc=assoc)
    return [ref.update(db[t][dm[t]]) for t in range(db.shape[0])]


def _assert_identical_streams(out, ref_frames, ctx=""):
    for t, ref_t in enumerate(ref_frames):
        em = np.asarray(out.emit[t, 0])
        uids = np.asarray(out.uid[t, 0])
        ids_ours = sorted(int(u) for u in uids[em])
        ids_ref = sorted(int(o[4]) for o in ref_t)
        assert ids_ours == ids_ref, f"frame {t} {ctx}"
        boxes_ours = {int(u): np.asarray(out.boxes[t, 0, k])
                      for k, u in enumerate(uids) if em[k]}
        for o in ref_t:
            np.testing.assert_allclose(boxes_ours[int(o[4])], o[:4],
                                       rtol=1e-3, atol=0.5,
                                       err_msg=f"frame {t} uid {o[4]} {ctx}")


@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@pytest.mark.parametrize("seed,max_objects", [(0, 4), (13, 6)])
def test_oracle_parity_deterministic(use_kernels, assoc, seed, max_objects):
    db, dm = _scene(seed, max_objects)
    out = _run_engine(db, dm, use_kernels, assoc)
    ref_frames = _run_ref(db, dm, assoc)
    _assert_identical_streams(out, ref_frames,
                              f"(uk={use_kernels} assoc={assoc} seed={seed})")


# ------------------------------------------------- chunk-resident megakernel
# DESIGN.md §9: the megakernel runs a whole planned chunk inside one
# pallas_call; off-TPU `mode="auto"` resolves both dispatch modes to the
# same-math oracle, so parity here is *bitwise* (the per-frame scan body
# and the in-kernel chunk body are the identical elementwise op chain).

_CHUNK_LANES = 3
_CHUNK_DETS = 5


def _chunk_engines(assoc):
    key = ("chunk", assoc)
    if key not in _ENGINES:
        def mk(chunk_kernel):
            return SortEngine(SortConfig(
                max_trackers=8, max_detections=_CHUNK_DETS,
                use_kernels=True, assoc=assoc, chunk_kernel=chunk_kernel))
        _ENGINES[key] = (mk(False), mk(True))
    return _ENGINES[key]


def _chunk_traffic(seed, num_frames, lanes=_CHUNK_LANES, d=_CHUNK_DETS):
    """A planned serving chunk with adversarial lifecycle traffic: partial
    detection masks, lanes going inactive mid-chunk, and interior resets
    (mid-chunk lane recycles) on top of the admission reset at frame 0."""
    rng = np.random.default_rng(seed)
    tl = rng.uniform(0.0, 180.0, size=(num_frames, lanes, d, 2))
    wh = rng.uniform(8.0, 40.0, size=(num_frames, lanes, d, 2))
    det = np.concatenate([tl, tl + wh], axis=-1).astype(np.float32)
    dm = rng.random((num_frames, lanes, d)) < 0.7
    active = rng.random((num_frames, lanes)) < 0.85
    reset = rng.random((num_frames, lanes)) < 0.15
    reset[0] = True
    return tuple(jnp.asarray(a) for a in (det, dm, active, reset))


def _assert_chunk_equal(a, b, ctx=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), ctx
    for i, (xa, xb) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=f"leaf {i} {ctx}")


@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
def test_megakernel_chunk_bit_identical_to_per_frame_scan(assoc):
    """Two sequential chunks (state carried across the boundary) through
    the per-frame-scan dispatch mode and the megakernel dispatch mode:
    every state leaf and every output is bit-identical."""
    eng_scan, eng_mega = _chunk_engines(assoc)
    st_a = eng_scan.init_ragged(_CHUNK_LANES)
    st_b = eng_mega.init_ragged(_CHUNK_LANES)
    for chunk_idx in range(2):
        det, dm, active, reset = _chunk_traffic(100 + chunk_idx, 9)
        st_a, out_a = eng_scan.run_chunk_ragged(st_a, det, dm, active, reset)
        st_b, out_b = eng_mega.run_chunk_ragged(st_b, det, dm, active, reset)
        ctx = f"(assoc={assoc} chunk={chunk_idx})"
        _assert_chunk_equal(st_a, st_b, ctx)
        _assert_chunk_equal(out_a, out_b, ctx)


def test_all_inactive_chunk_is_bitwise_noop():
    """A chunk whose lanes are all inactive must leave the lane state
    bit-identical and emit nothing — the scheduler relies on idle drain
    tails being free of side effects under both dispatch modes."""
    _, eng = _chunk_engines("greedy")
    st = eng.init_ragged(_CHUNK_LANES)
    det, dm, active, reset = _chunk_traffic(7, 6)
    st, _ = eng.run_chunk_ragged(st, det, dm, active, reset)  # warm state
    det2, dm2, _, _ = _chunk_traffic(8, 6)
    idle = jnp.zeros((6, _CHUNK_LANES), bool)
    st2, out = eng.run_chunk_ragged(st, det2, dm2, idle, idle)
    _assert_chunk_equal(st, st2, "(all-inactive chunk)")
    assert not np.asarray(out.emit).any()


@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
def test_megakernel_ragged_tail_chunk(assoc):
    """A tail chunk where lanes run out of frames at different steps
    (ragged drain) stays bit-identical across dispatch modes."""
    eng_scan, eng_mega = _chunk_engines(assoc)
    det, dm, _, _ = _chunk_traffic(42, 7)
    # lane l active for its first (7 - 2*l) steps only — ragged tail
    active = np.zeros((7, _CHUNK_LANES), bool)
    for lane in range(_CHUNK_LANES):
        active[:7 - 2 * lane, lane] = True
    reset = np.zeros((7, _CHUNK_LANES), bool)
    reset[0] = True
    active, reset = jnp.asarray(active), jnp.asarray(reset)
    st_a, out_a = eng_scan.run_chunk_ragged(
        eng_scan.init_ragged(_CHUNK_LANES), det, dm, active, reset)
    st_b, out_b = eng_mega.run_chunk_ragged(
        eng_mega.init_ragged(_CHUNK_LANES), det, dm, active, reset)
    _assert_chunk_equal(st_a, st_b, f"(ragged tail, assoc={assoc})")
    _assert_chunk_equal(out_a, out_b, f"(ragged tail, assoc={assoc})")


@pytest.mark.slow
@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@settings(max_examples=8, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**31 - 1), max_objects=st.sampled_from([4, 6]))
def test_oracle_parity_property(use_kernels, assoc, seed, max_objects):
    """Hypothesis sweep over scene seeds and object densities: the batched
    engine (every path x assoc combination) and the per-stream numpy
    oracle emit identical track streams."""
    db, dm = _scene(seed, max_objects)
    out = _run_engine(db, dm, use_kernels, assoc)
    ref_frames = _run_ref(db, dm, assoc)
    _assert_identical_streams(out, ref_frames,
                              f"(uk={use_kernels} assoc={assoc} seed={seed})")


# --------------------------------- multiclass / composed costs (DESIGN.md §10)
# The grid grows by cost mode x class count: the composed score
# (IoU ⊕ Mahalanobis gate ⊕ embedding) and the class partition must match
# the extended scipy-backed oracle on every engine path under both
# association modes, and the megakernel dispatch mode must stay bitwise
# equal to the per-frame scan with the new operands threaded through.

MC_FRAMES = 30
MC_EMBED = 4
COSTS = [("iou", cost_mod.IOU),
         ("maha", cost_mod.iou_maha()),
         ("embed", cost_mod.iou_embed(MC_EMBED))]
_MC_SCENE: dict = {}


def _mc_scene():
    if "scene" not in _MC_SCENE:
        _MC_SCENE["scene"] = generate_multiclass_scene(
            SceneConfig(num_frames=MC_FRAMES, max_objects=5, seed=5),
            num_classes=3, embed_dim=MC_EMBED)
    return _MC_SCENE["scene"]


def _run_engine_mc(db, dm, dc, de, use_kernels, assoc, spec, nc):
    key = ("mc", db.shape[1], use_kernels, assoc, spec, nc)
    if key not in _ENGINES:
        eng = SortEngine(SortConfig(max_trackers=16,
                                    max_detections=db.shape[1],
                                    use_kernels=use_kernels, assoc=assoc,
                                    cost=spec, num_classes=nc))
        kw_names = (("det_class",) if nc > 1 else ()) + \
                   (("det_embed",) if spec.uses_embed else ())

        def run_fn(state, b, m, *ops, eng=eng, kw_names=kw_names):
            return eng.run(state, b, m, **dict(zip(kw_names, ops)))

        _ENGINES[key] = (eng, jax.jit(run_fn), kw_names)
    eng, run_fn, kw_names = _ENGINES[key]
    ops = {"det_class": jnp.asarray(dc)[:, None],
           "det_embed": jnp.asarray(de)[:, None]}
    _, out = run_fn(eng.init(1), jnp.asarray(db)[:, None],
                    jnp.asarray(dm)[:, None],
                    *[ops[n] for n in kw_names])
    return out


def _run_ref_mc(db, dm, dc, de, assoc, spec, nc):
    """Mirror the engine's operand gating: classes thread only when the
    config partitions (nc>1), embeds only when the cost consumes them."""
    ref = RefSort(assoc=assoc, cost=spec, num_classes=nc)
    return [ref.update(db[t][dm[t]],
                       dc[t][dm[t]] if nc > 1 else None,
                       de[t][dm[t]] if spec.uses_embed else None)
            for t in range(db.shape[0])]


def _assert_identical_mc_streams(out, ref_frames, ctx=""):
    """uid AND class of every emitted track match the oracle per frame."""
    for t, ref_t in enumerate(ref_frames):
        em = np.asarray(out.emit[t, 0])
        uids = np.asarray(out.uid[t, 0])
        clss = np.asarray(out.cls[t, 0])
        ours = sorted((int(u), int(c)) for u, c in zip(uids[em], clss[em]))
        ref = sorted((int(o[4]), int(o[5])) for o in ref_t)
        assert ours == ref, f"frame {t} {ctx}"
        boxes_ours = {int(u): np.asarray(out.boxes[t, 0, k])
                      for k, u in enumerate(uids) if em[k]}
        for o in ref_t:
            np.testing.assert_allclose(boxes_ours[int(o[4])], o[:4],
                                       rtol=1e-3, atol=0.5,
                                       err_msg=f"frame {t} uid {o[4]} {ctx}")


@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@pytest.mark.parametrize("cost_name,spec", COSTS)
@pytest.mark.parametrize("nc", [1, 3])
def test_oracle_parity_multiclass(use_kernels, assoc, cost_name, spec, nc):
    """path x assoc x cost-mode x {1,3}-classes vs the extended oracle."""
    if cost_name == "iou" and nc == 1:
        pytest.skip("exact pre-cost config; covered by the original grid")
    db_g, dm_g, _, db, dm, dc, de = _mc_scene()
    del db_g, dm_g
    out = _run_engine_mc(db, dm, dc, de, use_kernels, assoc, spec, nc)
    ref_frames = _run_ref_mc(db, dm, dc, de, assoc, spec, nc)
    _assert_identical_mc_streams(
        out, ref_frames,
        f"(uk={use_kernels} assoc={assoc} cost={cost_name} nc={nc})")


@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
@pytest.mark.parametrize("cost_name,spec", COSTS)
def test_megakernel_multiclass_bit_identical(assoc, cost_name, spec):
    """Dispatch-mode leg of the multiclass grid: with det_class/det_embed
    threaded through the chunk path, the megakernel stays bit-identical to
    the per-frame scan (state, boxes, uids, classes, embeds)."""
    nc = 3
    rng = np.random.default_rng(11)

    def mk(chunk_kernel):
        return SortEngine(SortConfig(
            max_trackers=8, max_detections=_CHUNK_DETS, use_kernels=True,
            assoc=assoc, chunk_kernel=chunk_kernel, cost=spec,
            num_classes=nc))

    eng_scan, eng_mega = mk(False), mk(True)
    st_a = eng_scan.init_ragged(_CHUNK_LANES)
    st_b = eng_mega.init_ragged(_CHUNK_LANES)
    for chunk_idx in range(2):
        det, dm, active, reset = _chunk_traffic(200 + chunk_idx, 7)
        dc = jnp.asarray(rng.integers(
            0, nc, (7, _CHUNK_LANES, _CHUNK_DETS)).astype(np.int32))
        de = jnp.asarray(rng.normal(size=(
            7, _CHUNK_LANES, _CHUNK_DETS, MC_EMBED)).astype(np.float32))
        kw = {"det_class": dc}
        if spec.uses_embed:
            kw["det_embed"] = de
        st_a, out_a = eng_scan.run_chunk_ragged(st_a, det, dm, active,
                                                reset, **kw)
        st_b, out_b = eng_mega.run_chunk_ragged(st_b, det, dm, active,
                                                reset, **kw)
        ctx = f"(assoc={assoc} cost={cost_name} chunk={chunk_idx})"
        _assert_chunk_equal(st_a, st_b, ctx)
        _assert_chunk_equal(out_a, out_b, ctx)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_cross_class_never_matched(use_kernels):
    """Crossing-paths regression: all objects pass through the image
    center mid-sequence (a cross-class pair momentarily has the best
    IoU), yet the partition only lets a track be updated by dets of its
    own class — so after the crossing every track sits back on a gt
    trajectory of ITS class, each uid keeps one class for life, and the
    whole stream still matches the oracle."""
    gtb, _, gcls, db, dm, dc, de = generate_crossing_scene(
        num_frames=40, num_objects=4, num_classes=2, embed_dim=MC_EMBED,
        seed=2)
    out = _run_engine_mc(db, dm, dc, de, use_kernels, "hungarian",
                         cost_mod.IOU, 2)
    _assert_identical_mc_streams(
        out, _run_ref_mc(db, dm, dc, de, "hungarian", cost_mod.IOU, 2),
        f"(crossing uk={use_kernels})")
    uid_cls: dict = {}
    for t in range(db.shape[0]):
        em = np.asarray(out.emit[t, 0])
        uids = np.asarray(out.uid[t, 0])
        clss = np.asarray(out.cls[t, 0])
        for k in np.where(em)[0]:
            u, c = int(uids[k]), int(clss[k])
            # class frozen at birth, stable for the track's whole lifetime
            assert uid_cls.setdefault(u, c) == c, f"uid {u} changed class"
            if t >= db.shape[0] - 5:
                # well past the crossing: only same-class detections ever
                # updated this track, so its box is glued to a gt
                # trajectory of its own class, far from the other class's
                dist = np.abs(gtb[t] - np.asarray(out.boxes[t, 0, k])).max(-1)
                same, other = dist[gcls == c], dist[gcls != c]
                assert same.min() < 5.0, (t, u, same.min())
                assert same.min() < other.min(), (t, u)
    # both classes actually tracked through the crossing
    assert set(uid_cls.values()) == {0, 1}


def test_class_preserved_through_lane_recycling():
    """Recycled lanes must not leak the previous occupant's classes: two
    sequences with disjoint class alphabets ({0,1} then {2,3}) served
    through ONE lane — every emitted class stays inside its own
    sequence's alphabet, and within a sequence each uid keeps one class."""
    from repro.serve import StreamScheduler

    scenes = []
    for off, seed in ((0, 3), (2, 4)):
        _, _, _, db, dm, dc, de = generate_crossing_scene(
            num_frames=12, num_objects=4, num_classes=2,
            embed_dim=MC_EMBED, seed=seed)
        scenes.append((db, dm, dc + off, de))
    eng = SortEngine(SortConfig(max_trackers=8,
                                max_detections=scenes[0][0].shape[1],
                                use_kernels=True, cost=cost_mod.IOU,
                                num_classes=4))
    sched = StreamScheduler(eng, num_lanes=1, chunk=5)
    for i, (db, dm, dc, de) in enumerate(scenes):
        sched.submit(f"s{i}", db, dm, det_class=dc)
    results = sched.run()
    assert [r.name for r in results] == ["s0", "s1"]
    for res, alphabet in zip(results, ({0, 1}, {2, 3})):
        seen: dict = {}
        for t in range(res.num_frames):
            for k in np.where(res.emit[t])[0]:
                u, c = int(res.uid[t][k]), int(res.cls[t][k])
                assert c in alphabet, (res.name, t, u, c)
                assert seen.setdefault(u, c) == c, (res.name, u)
        assert seen, res.name
