"""End-to-end oracle parity: numpy reference SORT == batched engine.

Runs whole synthetic sequences through ``core.ref_numpy.Sort`` (the
faithful per-stream scipy-backed port of the original implementation the
paper profiles) and through ``SortEngine`` on **both** execution paths
under **both** association modes (DESIGN.md §6):

* ``use_kernels=False`` x ``assoc in {"hungarian", "greedy"}``
* ``use_kernels=True``  x ``assoc in {"hungarian", "greedy"}`` — the
  fused lane path; with ``"hungarian"`` its JV solve runs as the
  lane-batched stage feeding the single fused dispatch, and this test is
  the fused-Hungarian vs scipy ``linear_sum_assignment`` lockdown.

Track identities must match exactly; boxes match to float32-vs-float64
tolerance.  Hypothesis drives scene seeds and object densities; the
engines are cached per (shape, path, assoc) so examples reuse
compilations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import SortConfig, SortEngine
from repro.core.ref_numpy import Sort as RefSort
from repro.data.synthetic import SceneConfig, generate_scene

NUM_FRAMES = 45  # fixed so every hypothesis example reuses the jit cache
PATHS = [(False, "hungarian"), (False, "greedy"),
         (True, "hungarian"), (True, "greedy")]
_ENGINES: dict = {}


def _scene(seed, max_objects):
    _, _, db, dm = generate_scene(SceneConfig(
        num_frames=NUM_FRAMES, max_objects=max_objects, seed=seed))
    return db, dm


def _run_engine(db, dm, use_kernels, assoc):
    key = (db.shape[1], use_kernels, assoc)
    if key not in _ENGINES:
        eng = SortEngine(SortConfig(max_trackers=16,
                                    max_detections=db.shape[1],
                                    use_kernels=use_kernels, assoc=assoc))
        _ENGINES[key] = (eng, jax.jit(eng.run))
    eng, run_fn = _ENGINES[key]
    _, out = run_fn(eng.init(1), jnp.asarray(db)[:, None],
                    jnp.asarray(dm)[:, None])
    return out


def _run_ref(db, dm, assoc):
    ref = RefSort(assoc=assoc)
    return [ref.update(db[t][dm[t]]) for t in range(db.shape[0])]


def _assert_identical_streams(out, ref_frames, ctx=""):
    for t, ref_t in enumerate(ref_frames):
        em = np.asarray(out.emit[t, 0])
        uids = np.asarray(out.uid[t, 0])
        ids_ours = sorted(int(u) for u in uids[em])
        ids_ref = sorted(int(o[4]) for o in ref_t)
        assert ids_ours == ids_ref, f"frame {t} {ctx}"
        boxes_ours = {int(u): np.asarray(out.boxes[t, 0, k])
                      for k, u in enumerate(uids) if em[k]}
        for o in ref_t:
            np.testing.assert_allclose(boxes_ours[int(o[4])], o[:4],
                                       rtol=1e-3, atol=0.5,
                                       err_msg=f"frame {t} uid {o[4]} {ctx}")


@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@pytest.mark.parametrize("seed,max_objects", [(0, 4), (13, 6)])
def test_oracle_parity_deterministic(use_kernels, assoc, seed, max_objects):
    db, dm = _scene(seed, max_objects)
    out = _run_engine(db, dm, use_kernels, assoc)
    ref_frames = _run_ref(db, dm, assoc)
    _assert_identical_streams(out, ref_frames,
                              f"(uk={use_kernels} assoc={assoc} seed={seed})")


# ------------------------------------------------- chunk-resident megakernel
# DESIGN.md §9: the megakernel runs a whole planned chunk inside one
# pallas_call; off-TPU `mode="auto"` resolves both dispatch modes to the
# same-math oracle, so parity here is *bitwise* (the per-frame scan body
# and the in-kernel chunk body are the identical elementwise op chain).

_CHUNK_LANES = 3
_CHUNK_DETS = 5


def _chunk_engines(assoc):
    key = ("chunk", assoc)
    if key not in _ENGINES:
        def mk(chunk_kernel):
            return SortEngine(SortConfig(
                max_trackers=8, max_detections=_CHUNK_DETS,
                use_kernels=True, assoc=assoc, chunk_kernel=chunk_kernel))
        _ENGINES[key] = (mk(False), mk(True))
    return _ENGINES[key]


def _chunk_traffic(seed, num_frames, lanes=_CHUNK_LANES, d=_CHUNK_DETS):
    """A planned serving chunk with adversarial lifecycle traffic: partial
    detection masks, lanes going inactive mid-chunk, and interior resets
    (mid-chunk lane recycles) on top of the admission reset at frame 0."""
    rng = np.random.default_rng(seed)
    tl = rng.uniform(0.0, 180.0, size=(num_frames, lanes, d, 2))
    wh = rng.uniform(8.0, 40.0, size=(num_frames, lanes, d, 2))
    det = np.concatenate([tl, tl + wh], axis=-1).astype(np.float32)
    dm = rng.random((num_frames, lanes, d)) < 0.7
    active = rng.random((num_frames, lanes)) < 0.85
    reset = rng.random((num_frames, lanes)) < 0.15
    reset[0] = True
    return tuple(jnp.asarray(a) for a in (det, dm, active, reset))


def _assert_chunk_equal(a, b, ctx=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), ctx
    for i, (xa, xb) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb),
                                      err_msg=f"leaf {i} {ctx}")


@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
def test_megakernel_chunk_bit_identical_to_per_frame_scan(assoc):
    """Two sequential chunks (state carried across the boundary) through
    the per-frame-scan dispatch mode and the megakernel dispatch mode:
    every state leaf and every output is bit-identical."""
    eng_scan, eng_mega = _chunk_engines(assoc)
    st_a = eng_scan.init_ragged(_CHUNK_LANES)
    st_b = eng_mega.init_ragged(_CHUNK_LANES)
    for chunk_idx in range(2):
        det, dm, active, reset = _chunk_traffic(100 + chunk_idx, 9)
        st_a, out_a = eng_scan.run_chunk_ragged(st_a, det, dm, active, reset)
        st_b, out_b = eng_mega.run_chunk_ragged(st_b, det, dm, active, reset)
        ctx = f"(assoc={assoc} chunk={chunk_idx})"
        _assert_chunk_equal(st_a, st_b, ctx)
        _assert_chunk_equal(out_a, out_b, ctx)


def test_all_inactive_chunk_is_bitwise_noop():
    """A chunk whose lanes are all inactive must leave the lane state
    bit-identical and emit nothing — the scheduler relies on idle drain
    tails being free of side effects under both dispatch modes."""
    _, eng = _chunk_engines("greedy")
    st = eng.init_ragged(_CHUNK_LANES)
    det, dm, active, reset = _chunk_traffic(7, 6)
    st, _ = eng.run_chunk_ragged(st, det, dm, active, reset)  # warm state
    det2, dm2, _, _ = _chunk_traffic(8, 6)
    idle = jnp.zeros((6, _CHUNK_LANES), bool)
    st2, out = eng.run_chunk_ragged(st, det2, dm2, idle, idle)
    _assert_chunk_equal(st, st2, "(all-inactive chunk)")
    assert not np.asarray(out.emit).any()


@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
def test_megakernel_ragged_tail_chunk(assoc):
    """A tail chunk where lanes run out of frames at different steps
    (ragged drain) stays bit-identical across dispatch modes."""
    eng_scan, eng_mega = _chunk_engines(assoc)
    det, dm, _, _ = _chunk_traffic(42, 7)
    # lane l active for its first (7 - 2*l) steps only — ragged tail
    active = np.zeros((7, _CHUNK_LANES), bool)
    for lane in range(_CHUNK_LANES):
        active[:7 - 2 * lane, lane] = True
    reset = np.zeros((7, _CHUNK_LANES), bool)
    reset[0] = True
    active, reset = jnp.asarray(active), jnp.asarray(reset)
    st_a, out_a = eng_scan.run_chunk_ragged(
        eng_scan.init_ragged(_CHUNK_LANES), det, dm, active, reset)
    st_b, out_b = eng_mega.run_chunk_ragged(
        eng_mega.init_ragged(_CHUNK_LANES), det, dm, active, reset)
    _assert_chunk_equal(st_a, st_b, f"(ragged tail, assoc={assoc})")
    _assert_chunk_equal(out_a, out_b, f"(ragged tail, assoc={assoc})")


@pytest.mark.slow
@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@settings(max_examples=8, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**31 - 1), max_objects=st.sampled_from([4, 6]))
def test_oracle_parity_property(use_kernels, assoc, seed, max_objects):
    """Hypothesis sweep over scene seeds and object densities: the batched
    engine (every path x assoc combination) and the per-stream numpy
    oracle emit identical track streams."""
    db, dm = _scene(seed, max_objects)
    out = _run_engine(db, dm, use_kernels, assoc)
    ref_frames = _run_ref(db, dm, assoc)
    _assert_identical_streams(out, ref_frames,
                              f"(uk={use_kernels} assoc={assoc} seed={seed})")
