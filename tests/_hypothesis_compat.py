"""Graceful degradation when hypothesis is absent.

The end-to-end suites mix deterministic cases with hypothesis properties
in one file; a module-level ``pytest.importorskip`` would skip both.
Importing ``given``/``settings``/``st`` from here keeps collection green
and the deterministic cases running — only the property tests skip.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    def settings(**_kw):
        return lambda f: f

    def given(**_kw):
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    class st:  # noqa: N801 — stand-in strategies module
        integers = lists = sampled_from = staticmethod(
            lambda *a, **k: None)
