"""Model-family correctness: fwd/loss/grad finiteness + teacher-forced
decode == full forward for every causal family; SSD algebra checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2
from repro.models.config import ModelConfig
from repro.models.layers import ParamBuilder
from repro.models.model import build_model
from repro.models.transformer import Parallel, plan_segments

FAMILIES = {
    "gqa": ModelConfig(num_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=100, max_seq_len=64,
                       dtype="float32", qkv_bias=True),
    "mla": ModelConfig(num_layers=2, d_model=64, n_heads=4, d_ff=128,
                       vocab_size=100, attn_type="mla", q_lora_rank=32,
                       kv_lora_rank=32, qk_nope_head_dim=16,
                       qk_rope_head_dim=8, v_head_dim=16, max_seq_len=64,
                       dtype="float32"),
    "mla_moe": ModelConfig(num_layers=3, d_model=64, n_heads=4, d_ff=128,
                           vocab_size=100, attn_type="mla", kv_lora_rank=32,
                           qk_nope_head_dim=16, qk_rope_head_dim=8,
                           v_head_dim=16, moe=True, n_routed_experts=8,
                           n_shared_experts=1, moe_top_k=2, moe_d_ff=32,
                           first_k_dense=1, moe_capacity_factor=16.0,
                           max_seq_len=64, dtype="float32"),
    "ssm": ModelConfig(num_layers=3, d_model=64, block_type="ssm", d_ff=0,
                       vocab_size=100, ssm_state=16, ssm_head_dim=16,
                       ssm_chunk=8, max_seq_len=64, dtype="float32"),
    "hybrid": ModelConfig(num_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=100, block_type="hybrid",
                          sliding_window=8, global_attn_layers=(0, 2),
                          ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                          max_seq_len=64, dtype="float32"),
}


def _batch(cfg, b=2, l=16):
    return {"tokens": (jnp.arange(b * l).reshape(b, l) * 7) % cfg.vocab_size,
            "labels": jnp.ones((b, l), jnp.int32)}


@pytest.mark.parametrize("family", list(FAMILIES))
def test_forward_loss_grad_finite(family):
    cfg = FAMILIES[family]
    m = build_model(cfg)
    params, specs = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("family", list(FAMILIES))
def test_decode_matches_forward(family):
    cfg = FAMILIES[family]
    m = build_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    full = jax.jit(m.forward)(params, batch)
    lg, caches = m.prefill(params, {"tokens": batch["tokens"][:, :8]},
                           Parallel(), 32)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 7]),
                               rtol=5e-3, atol=5e-3)
    for t in range(8, 16):
        lg, caches = m.decode(params, batch["tokens"][:, t:t + 1],
                              jnp.full((2,), t, jnp.int32), caches)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_encoder_and_vlm_forward():
    enc = ModelConfig(num_layers=2, d_model=64, n_heads=4, d_ff=128,
                      vocab_size=50, causal=False, modality="audio",
                      max_seq_len=64, dtype="float32")
    m = build_model(enc)
    params, _ = m.init(jax.random.PRNGKey(0))
    batch = {"feats": jnp.ones((2, 16, 64)),
             "mask_spans": jnp.zeros((2, 16), bool),
             "labels": jnp.ones((2, 16), jnp.int32),
             "loss_mask": jnp.ones((2, 16))}
    assert bool(jnp.isfinite(m.loss(params, batch)))

    vlm = ModelConfig(num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=100, modality="vision",
                      frontend_dim=32, num_patches=4, max_seq_len=64,
                      dtype="float32")
    m = build_model(vlm)
    params, _ = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 12), jnp.int32),
             "patches": jnp.ones((2, 4, 32)),
             "labels": jnp.ones((2, 12), jnp.int32)}
    logits = m.forward(params, batch)
    assert logits.shape == (2, 16, vlm.padded_vocab)  # patches + text
    assert bool(jnp.isfinite(m.loss(params, batch)))


def test_segment_plan():
    cfg = FAMILIES["hybrid"]
    segs = plan_segments(cfg)
    assert [s.num_layers for s in segs] == [1, 1, 1]
    assert [s.window for s in segs] == [None, 8, None]
    ds = FAMILIES["mla_moe"]
    segs = plan_segments(ds)
    assert [(s.num_layers, s.use_moe) for s in segs] == [(1, False), (2, True)]


def test_ssd_chunked_vs_sequential():
    cfg = ModelConfig(d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                      ssm_groups=2)
    rng = np.random.default_rng(0)
    h, p, g, n = cfg.ssm_heads, cfg.ssm_head_dim, 2, cfg.ssm_state
    x = jnp.asarray(rng.normal(size=(2, 32, h, p)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(2, 32, h))).astype(np.float32)
                     * 0.5)
    a = -jnp.asarray(np.abs(rng.normal(size=(h,))).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 32, g, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(2, 32, g, n)).astype(np.float32))
    y1, h1 = mamba2.ssd_chunked(x, dt, a, b, c, cfg)
    y2, h2 = mamba2.ssd_sequential(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_invariance():
    """Same output whatever the chunk size — the SSD identity."""
    base = ModelConfig(d_model=32, ssm_state=8, ssm_head_dim=8, ssm_chunk=4)
    rng = np.random.default_rng(1)
    h, p, n = base.ssm_heads, base.ssm_head_dim, base.ssm_state
    x = jnp.asarray(rng.normal(size=(1, 24, h, p)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 24, h))).astype(np.float32))
    a = -jnp.asarray(np.abs(rng.normal(size=(h,))).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(1, 24, 1, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(1, 24, 1, n)).astype(np.float32))
    outs = []
    for q in (4, 8, 24):
        cfg = dataclasses.replace(base, ssm_chunk=q)
        y, _ = mamba2.ssd_chunked(x, dt, a, b, c, cfg)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)
