"""Loop-aware HLO analyzer: trip-count multiplication must recover the
analytic FLOPs that compiled.cost_analysis() undercounts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_text


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_multiplied():
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    res = analyze_text(_compiled_text(scanned, x, w))
    expect = 10 * 2 * 8 * 64 * 64
    assert abs(res["flops"] - expect) / expect < 0.05, res["flops"]


def test_single_dot_flops():
    a = jnp.zeros((32, 128), jnp.float32)
    b = jnp.zeros((128, 16), jnp.float32)
    res = analyze_text(_compiled_text(lambda a, b: a @ b, a, b))
    expect = 2 * 32 * 128 * 16
    assert abs(res["flops"] - expect) / expect < 0.05, res["flops"]


def test_nested_scan():
    x = jnp.zeros((4, 16), jnp.float32)
    w = jnp.zeros((16, 16), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    res = analyze_text(_compiled_text(nested, x, w))
    expect = 5 * 3 * 2 * 4 * 16 * 16
    assert abs(res["flops"] - expect) / expect < 0.05, res["flops"]


def test_hbm_bytes_nonzero_and_sane():
    a = jnp.zeros((256, 256), jnp.float32)
    res = analyze_text(_compiled_text(lambda a: (a + 1.0) * 2.0, a))
    # one fused elementwise op: read + write 256KiB each
    assert 2 * 256 * 256 * 4 <= res["hbm_bytes"] <= 6 * 256 * 256 * 4


def test_grad_flops_scale():
    """Backward of y = sum(x@w) adds ~2x the forward dot flops."""
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((32, 64), jnp.float32)
    fwd = analyze_text(_compiled_text(lambda x, w: (x @ w).sum(), x, w))
    bwd = analyze_text(_compiled_text(
        jax.grad(lambda x, w: (x @ w).sum(), argnums=(0, 1)), x, w))
    assert bwd["flops"] >= 1.8 * fwd["flops"]
