"""Persistent lane-layout state + fused frame kernel (DESIGN.md §2).

Covers: exact round-tripping of the lane conversions (including stream /
batch counts that are NOT multiples of the lane block, i.e. padding edge
cases), equivalence of the lane-persistent fused path with the legacy
per-phase path, the ``SortConfig.use_kernels`` wiring, the lane-layout
greedy port, and the single-dispatch Pallas kernel in interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SortConfig, SortEngine, lane_state_of, slots,
                        sort_state_of)
from repro.core.greedy import greedy_assign, greedy_assign_lane, \
    greedy_iou_fn_for_engine
from repro.data.synthetic import SceneConfig, generate_scene
from repro.kernels import frame, ops, ref


def _scene(seed, frames=30, objects=6):
    _, _, db, dm = generate_scene(
        SceneConfig(num_frames=frames, max_objects=objects, seed=seed))
    return jnp.asarray(db), jnp.asarray(dm)


def _rand_state(eng, s, seed=0):
    """An init() state mutated to a non-trivial population."""
    rng = np.random.default_rng(seed)
    st = eng.init(s)
    t = eng.config.max_trackers
    x = rng.normal(size=(s, t, 7)).astype(np.float32)
    a = rng.normal(size=(s, t, 7, 7)).astype(np.float32)
    p = a @ a.swapaxes(-1, -2) + np.eye(7, dtype=np.float32)
    alive = rng.random((s, t)) < 0.5
    uid = np.where(alive, rng.integers(1, 99, (s, t)), -1).astype(np.int32)
    pool = st.pool._replace(alive=jnp.asarray(alive), uid=jnp.asarray(uid),
                            age=jnp.asarray(rng.integers(0, 9, (s, t)),
                                            dtype=jnp.int32))
    return st._replace(x=jnp.asarray(x), p=jnp.asarray(p), pool=pool,
                       frame_count=jnp.asarray(rng.integers(0, 9, (s,)),
                                               dtype=jnp.int32))


# ------------------------------------------------------- exact round trips
@pytest.mark.parametrize("s,block_s", [(1, 4), (3, 4), (4, 4), (5, 4),
                                       (7, 32), (33, 32)])
def test_lane_state_roundtrip_exact(s, block_s):
    """lane_state_of / sort_state_of are exact inverses for stream counts
    that do and do not divide the lane block (padding edge cases)."""
    eng = SortEngine(SortConfig(max_trackers=5, max_detections=4))
    st = _rand_state(eng, s, seed=s)
    back = sort_state_of(lane_state_of(st, block_s), s)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("s,t,block_b", [(1, 3, 8), (3, 5, 8), (2, 7, 64),
                                         (4, 4, 16), (5, 3, 128)])
def test_to_lane_from_lane_roundtrip_nonmultiple(s, t, block_b):
    """ops.to_lane / ops.from_lane are exact inverses when S*T is not a
    multiple of block_b."""
    rng = np.random.default_rng(s * 10 + t)
    x = jnp.asarray(rng.normal(size=(s, t, 7)).astype(np.float32))
    p = jnp.asarray(rng.normal(size=(s, t, 7, 7)).astype(np.float32))
    xl, pl_ = ops.to_lane(x, p, block_b)
    assert xl.shape[-1] % block_b == 0
    x2, p2 = ops.from_lane(xl, pl_, s, t)
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))


def test_lane_pool_transpose_involution():
    pool = slots.init_pool((3,), 5)
    back = slots.transpose_pool(slots.transpose_pool(pool))
    for a, b in zip(pool, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ greedy lane port
@pytest.mark.parametrize("seed", range(8))
def test_greedy_lane_matches_reference(seed):
    """Lane port == greedy_assign + scatter inversion, bit for bit
    (same flat argmax order => same tie-breaking)."""
    rng = np.random.default_rng(seed)
    d, t, b = rng.integers(1, 9), rng.integers(1, 9), 5
    iou = rng.random((b, d, t)).astype(np.float32)
    dm = rng.random((b, d)) < 0.8
    tm = rng.random((b, t)) < 0.8
    det_to_trk = np.asarray(greedy_assign(jnp.asarray(iou), jnp.asarray(dm),
                                          jnp.asarray(tm), 0.3))
    t2d_l, md_l = greedy_assign_lane(jnp.asarray(iou.transpose(1, 2, 0)),
                                     jnp.asarray(dm.T), jnp.asarray(tm.T),
                                     0.3)
    t2d_l, md_l = np.asarray(t2d_l).T, np.asarray(md_l).T   # back to [B, ...]
    for bi in range(b):
        want_t2d = np.full(t, -1, np.int32)
        for di, ti in enumerate(det_to_trk[bi]):
            if ti >= 0:
                want_t2d[ti] = di
        np.testing.assert_array_equal(t2d_l[bi], want_t2d)
        np.testing.assert_array_equal(md_l[bi], det_to_trk[bi] >= 0)


# ------------------------------------------------- fused kernel vs oracle
def test_fused_frame_kernel_matches_oracle():
    """Single-dispatch Pallas kernel (interpret mode) == pure-jnp oracle."""
    rng = np.random.default_rng(3)
    t, d, s, block_s = 6, 5, 8, 4
    x = jnp.asarray(rng.normal(size=(7, t, s)).astype(np.float32))
    a = rng.normal(size=(t, s, 7, 7)).astype(np.float32)
    p_sq = a @ a.swapaxes(-1, -2) + np.eye(7, dtype=np.float32)
    p = jnp.asarray(p_sq.reshape(t, s, 49).transpose(2, 0, 1).copy())
    xy = rng.uniform(0, 200, size=(d, 2, s))
    wh = rng.uniform(5, 100, size=(d, 2, s))
    det = jnp.asarray(np.concatenate([xy, xy + wh], 1).astype(np.float32))
    dm = jnp.asarray((rng.random((d, s)) < 0.8).astype(np.float32))
    alive = jnp.asarray((rng.random((t, s)) < 0.7).astype(np.float32))

    got = frame.fused_frame(x, p, det, dm, alive, iou_threshold=0.3,
                            block_s=block_s, interpret=True)
    want = ref.frame_lane(x, p, det, dm, alive, 0.3)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_array_equal(np.asarray(got[3]) > 0,
                                  np.asarray(want[3]))


def test_fused_frame_active_mask_matches_oracle():
    """The ragged-stream lane mask (DESIGN.md §3) inside the Pallas kernel
    (interpret mode) == oracle, and inactive lanes pass through untouched
    bit for bit."""
    rng = np.random.default_rng(17)
    t, d, s, block_s = 6, 5, 8, 4
    x = jnp.asarray(rng.normal(size=(7, t, s)).astype(np.float32))
    a = rng.normal(size=(t, s, 7, 7)).astype(np.float32)
    p_sq = a @ a.swapaxes(-1, -2) + np.eye(7, dtype=np.float32)
    p = jnp.asarray(p_sq.reshape(t, s, 49).transpose(2, 0, 1).copy())
    xy = rng.uniform(0, 200, size=(d, 2, s))
    wh = rng.uniform(5, 100, size=(d, 2, s))
    det = jnp.asarray(np.concatenate([xy, xy + wh], 1).astype(np.float32))
    dm = jnp.asarray((rng.random((d, s)) < 0.8).astype(np.float32))
    alive = jnp.asarray((rng.random((t, s)) < 0.7).astype(np.float32))
    act = jnp.asarray((rng.random((1, s)) < 0.5).astype(np.float32))

    got = frame.fused_frame(x, p, det, dm, alive, act, iou_threshold=0.3,
                            block_s=block_s, interpret=True)
    want = ref.frame_lane(x, p, det, dm, alive, 0.3, active=act)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_array_equal(np.asarray(got[3]) > 0,
                                  np.asarray(want[3]))
    # inactive lanes are exact no-ops: state untouched, no matches
    off = np.asarray(act)[0] == 0
    np.testing.assert_array_equal(np.asarray(got[0])[:, :, off],
                                  np.asarray(x)[:, :, off])
    np.testing.assert_array_equal(np.asarray(got[1])[:, :, off],
                                  np.asarray(p)[:, :, off])
    assert (np.asarray(got[2])[:, off] == -1).all()
    assert (np.asarray(got[3])[:, off] == 0).all()


def _rand_lane_operands(seed, t=6, d=5, s=8):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(7, t, s)).astype(np.float32))
    a = rng.normal(size=(t, s, 7, 7)).astype(np.float32)
    p_sq = a @ a.swapaxes(-1, -2) + np.eye(7, dtype=np.float32)
    p = jnp.asarray(p_sq.reshape(t, s, 49).transpose(2, 0, 1).copy())
    xy = rng.uniform(0, 200, size=(d, 2, s))
    wh = rng.uniform(5, 100, size=(d, 2, s))
    det = jnp.asarray(np.concatenate([xy, xy + wh], 1).astype(np.float32))
    dm = jnp.asarray((rng.random((d, s)) < 0.8).astype(np.float32))
    alive = jnp.asarray((rng.random((t, s)) < 0.7).astype(np.float32))
    act = jnp.asarray((rng.random((1, s)) < 0.5).astype(np.float32))
    return x, p, det, dm, alive, act


@pytest.mark.parametrize("seed", [5, 23])
@pytest.mark.parametrize("with_active", [False, True])
def test_frame_step_hungarian_kernel_matches_oracle(seed, with_active):
    """Fused-Hungarian kernel path (jitted JV stage + precomputed-
    assignment Pallas kernel, interpret mode) == the full jnp oracle
    (``ref.frame_lane(assoc="hungarian")``), including the ragged active
    mask: inactive lanes stay exact no-ops."""
    x, p, det, dm, alive, act = _rand_lane_operands(seed)
    active = act if with_active else None
    got = ops.frame_step(x, p, det, dm, alive, active, iou_threshold=0.3,
                         block_s=4, mode="interpret", assoc="hungarian")
    want = ops.frame_step(x, p, det, dm, alive, active, iou_threshold=0.3,
                          block_s=4, mode="ref", assoc="hungarian")
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(want[3]))
    if with_active:
        off = np.asarray(act)[0] == 0
        np.testing.assert_array_equal(np.asarray(got[0])[:, :, off],
                                      np.asarray(x)[:, :, off])
        np.testing.assert_array_equal(np.asarray(got[1])[:, :, off],
                                      np.asarray(p)[:, :, off])
        assert (np.asarray(got[2])[:, off] == -1).all()
        assert (~np.asarray(got[3])[:, off]).all()


@pytest.mark.parametrize("seed", range(6))
def test_associate_lane_hungarian_matches_engine_layout(seed):
    """``association.associate_lane`` (the fused path's solve+gate) ==
    ``associate_from_iou`` on the transposed batch, bit for bit — the
    per-lane JV problems are identical no matter where the batch axis
    lives."""
    from repro.core import association

    rng = np.random.default_rng(seed)
    d, t, b = rng.integers(1, 9), rng.integers(1, 9), 5
    iou = rng.random((b, d, t)).astype(np.float32)
    dmask = rng.random((b, d)) < 0.8
    tmask = rng.random((b, t)) < 0.8
    a = association.associate_from_iou(jnp.asarray(iou), jnp.asarray(dmask),
                                       jnp.asarray(tmask), 0.3)
    t2d_l, md_l = association.associate_lane(
        jnp.asarray(iou.transpose(1, 2, 0)), jnp.asarray(dmask.T),
        jnp.asarray(tmask.T), 0.3)
    np.testing.assert_array_equal(np.asarray(t2d_l).T,
                                  np.asarray(a.trk_to_det))
    np.testing.assert_array_equal(np.asarray(md_l).T,
                                  np.asarray(a.matched_det))


# ----------------------------------------- lane-persistent run() vs legacy
@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
@pytest.mark.parametrize("num_streams", [1, 3])
def test_lane_run_bit_identical_to_legacy_lane_math(num_streams, assoc):
    """Full run(): the lane-persistent path == the legacy per-phase engine
    driving the *same* lane-layout math (ref kernels + the same assoc
    mode, DESIGN.md §6) — same ops per element, so outputs match exactly.
    This is the fused-Hungarian bit-parity lockdown: the lane-batched JV
    stage + single dispatch equals the unfused Hungarian path."""
    db, dm = _scene(11, frames=40)
    d = db.shape[1]
    db = jnp.repeat(db[:, None], num_streams, 1)
    dm = jnp.repeat(dm[:, None], num_streams, 1)

    eng_lane = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                     use_kernels=True, assoc=assoc))
    _, out_lane = jax.jit(eng_lane.run)(eng_lane.init(num_streams), db, dm)

    pf, uf, jf = ops.engine_fns(use_ref=True)
    eng_legacy = SortEngine(
        SortConfig(max_trackers=16, max_detections=d, assoc=assoc),
        predict_fn=pf, update_fn=uf, iou_fn=jf,
        assoc_fn=(greedy_iou_fn_for_engine(0.3) if assoc == "greedy"
                  else None))
    _, out_legacy = jax.jit(eng_legacy.run)(eng_legacy.init(num_streams),
                                            db, dm)

    np.testing.assert_array_equal(np.asarray(out_lane.uid),
                                  np.asarray(out_legacy.uid))
    np.testing.assert_array_equal(np.asarray(out_lane.emit),
                                  np.asarray(out_legacy.emit))
    np.testing.assert_array_equal(np.asarray(out_lane.matched_det),
                                  np.asarray(out_legacy.matched_det))
    np.testing.assert_allclose(np.asarray(out_lane.boxes),
                               np.asarray(out_legacy.boxes),
                               rtol=1e-6, atol=1e-4)


# ------------------------------------------------ use_kernels flag wiring
@pytest.mark.parametrize("assoc", ["hungarian", "greedy"])
@pytest.mark.parametrize("seed", [0, 9])
def test_use_kernels_flag_selects_matching_fused_path(seed, assoc):
    """Regression for the once-dead SortConfig.use_kernels flag: True and
    False must produce matching tracks on a synthetic scene under either
    association mode — since PR 3 the fused path runs the *same*
    algorithm as the unfused one (float tolerance covers
    einsum-vs-unrolled op order)."""
    db, dm = _scene(seed)
    d = db.shape[1]
    db, dm = db[:, None], dm[:, None]
    outs = {}
    for flag in (False, True):
        eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                    use_kernels=flag, assoc=assoc))
        _, outs[flag] = jax.jit(eng.run)(eng.init(1), db, dm)
    np.testing.assert_array_equal(np.asarray(outs[True].uid),
                                  np.asarray(outs[False].uid))
    np.testing.assert_array_equal(np.asarray(outs[True].emit),
                                  np.asarray(outs[False].emit))
    np.testing.assert_array_equal(np.asarray(outs[True].matched_det),
                                  np.asarray(outs[False].matched_det))
    np.testing.assert_allclose(np.asarray(outs[True].boxes),
                               np.asarray(outs[False].boxes),
                               rtol=1e-3, atol=1e-2)


def test_sort_config_rejects_unknown_assoc():
    with pytest.raises(ValueError):
        SortEngine(SortConfig(assoc="auction"))


def test_use_kernels_single_step_matches_run():
    """step() under use_kernels (convert -> lane_step -> convert) advances
    identically to one run() frame."""
    db, dm = _scene(4, frames=3)
    d = db.shape[1]
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d,
                                use_kernels=True))
    st = eng.init(2)
    db2 = jnp.repeat(db[:, None], 2, 1)
    dm2 = jnp.repeat(dm[:, None], 2, 1)
    st1, out1 = jax.jit(eng.step)(st, db2[0], dm2[0])
    _, outs = jax.jit(eng.run)(st, db2[:1], dm2[:1])
    np.testing.assert_array_equal(np.asarray(out1.uid),
                                  np.asarray(outs.uid[0]))
    np.testing.assert_allclose(np.asarray(out1.boxes),
                               np.asarray(outs.boxes[0]), rtol=1e-6,
                               atol=1e-6)


def test_use_kernels_rejects_per_phase_injections():
    with pytest.raises(ValueError):
        SortEngine(SortConfig(use_kernels=True), iou_fn=lambda a, b: a)


# ------------------------------------------------ chunk megakernel pieces
def test_assign_slots_lane_unrolled_matches_scatter_version():
    """The kernel-safe unrolled rank matcher == slots.assign_slots_lane
    (cumsum + scatter) for random free/want masks, including pool
    exhaustion (more claimants than free slots)."""
    rng = np.random.default_rng(11)
    for t, d in [(4, 3), (6, 5), (3, 6), (8, 8)]:
        for _ in range(6):
            free = jnp.asarray(rng.random((t, 9)) < 0.5)
            want = jnp.asarray(rng.random((d, 9)) < 0.6)
            got = ref.assign_slots_lane_unrolled(free, want)
            want_out = slots.assign_slots_lane(free, want)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want_out))


def _chunk_operands(seed, f, t, d, s, dt=np.float32):
    """A fresh ChunkState plus a planned chunk with partial masks,
    mid-chunk inactivity, and interior resets."""
    from repro.core import kalman

    rng = np.random.default_rng(seed)
    p0 = kalman.initial_covariance_np().reshape(49).astype(dt)
    state = ref.ChunkState(
        x=jnp.zeros((7, t, s), dt),
        p=jnp.asarray(np.broadcast_to(p0[:, None, None],
                                      (49, t, s)).copy()),
        alive=jnp.zeros((t, s), jnp.int32),
        age=jnp.zeros((t, s), jnp.int32),
        hits=jnp.zeros((t, s), jnp.int32),
        hit_streak=jnp.zeros((t, s), jnp.int32),
        time_since_update=jnp.zeros((t, s), jnp.int32),
        uid=jnp.full((t, s), -1, jnp.int32),
        cls=jnp.full((t, s), -1, jnp.int32),
        next_uid=jnp.ones((1, s), jnp.int32),
        frame_count=jnp.zeros((1, s), jnp.int32),
        embed=jnp.zeros((0, t, s), dt),
    )
    xy = rng.uniform(0, 200, size=(f, d, 2, s))
    wh = rng.uniform(5, 60, size=(f, d, 2, s))
    det = jnp.asarray(np.concatenate([xy, xy + wh], 2).astype(dt))
    dm = jnp.asarray((rng.random((f, d, s)) < 0.75).astype(dt))
    active = jnp.asarray((rng.random((f, 1, s)) < 0.85).astype(dt))
    reset = np.zeros((f, 1, s), np.int32)
    reset[0] = 1
    reset |= (rng.random((f, 1, s)) < 0.1).astype(np.int32)
    return state, det, dm, active, jnp.asarray(reset)


@pytest.mark.parametrize("assoc", ["greedy", "hungarian"])
def test_fused_chunk_kernel_matches_chunk_oracle(assoc):
    """The chunk-resident megakernel (interpret mode) == ref.chunk_lane,
    bit for bit, over a full lifecycle chunk: state leaves and all five
    per-frame outputs (DESIGN.md §9)."""
    from repro.kernels import chunk

    f, t, d, s = 5, 4, 3, 8
    state, det, dm, active, reset = _chunk_operands(29, f, t, d, s)
    t2d = None
    if assoc == "hungarian":
        _, pre = ref.chunk_lane(state, det, dm, active, reset,
                                assoc="hungarian")
        t2d = pre.trk_to_det
    want_st, want = ref.chunk_lane(state, det, dm, active, reset, t2d,
                                   assoc=assoc)
    got_st, got = chunk.fused_chunk(state, det, dm, active, reset, t2d,
                                    assoc=assoc, block_s=4, interpret=True)
    for name, a, b in zip(ref.ChunkState._fields, got_st, want_st):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"state.{name} ({assoc})")
    np.testing.assert_array_equal(np.asarray(got.boxes),
                                  np.asarray(want.boxes))
    np.testing.assert_array_equal(np.asarray(got.uid),
                                  np.asarray(want.uid))
    np.testing.assert_array_equal(np.asarray(got.emit) > 0,
                                  np.asarray(want.emit))
    np.testing.assert_array_equal(np.asarray(got.trk_to_det),
                                  np.asarray(want.trk_to_det))
    np.testing.assert_array_equal(np.asarray(got.matched_det) > 0,
                                  np.asarray(want.matched_det))


def test_chunk_step_interpret_matches_ref_mode():
    """ops.chunk_step wiring: mode="interpret" (megakernel + Hungarian
    pre-pass plumbing) == mode="ref" for both associations."""
    f, t, d, s = 4, 4, 3, 8
    state, det, dm, active, reset = _chunk_operands(31, f, t, d, s)
    for assoc in ("greedy", "hungarian"):
        want_st, want = ops.chunk_step(state, det, dm, active, reset,
                                       mode="ref", assoc=assoc, block_s=4)
        got_st, got = ops.chunk_step(state, det, dm, active, reset,
                                     mode="interpret", assoc=assoc,
                                     block_s=4)
        for a, b in zip(jax.tree_util.tree_leaves(got_st),
                        jax.tree_util.tree_leaves(want_st)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=assoc)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=assoc)
