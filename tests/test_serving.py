"""Serving substrate: continuous batching loop on a smoke model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.model import build_model
from repro.models.transformer import Parallel
from repro.train.serve_step import ServeLoop, make_decode_step, make_prefill


def test_decode_step_greedy():
    cfg = registry.get_smoke("qwen1_5_0_5b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prefill = make_prefill(model, Parallel.local(), 64)
    logits, caches = prefill(params, {"tokens": jnp.ones((2, 8), jnp.int32)})
    step = jax.jit(make_decode_step(model, Parallel.local()))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    pos = jnp.full((2,), 8, jnp.int32)
    for _ in range(4):
        tok, pos, caches = step(params, tok, pos, caches)
        assert tok.shape == (2, 1)
        assert bool((tok >= 0).all()) and bool((tok < cfg.padded_vocab).all())
    assert int(pos[0]) == 12


def test_serve_loop_continuous_batching():
    cfg = registry.get_smoke("qwen1_5_0_5b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    loop = ServeLoop(model=model, params=params, par=Parallel.local(),
                     num_slots=2, cache_len=32, eos_id=-1)  # never EOS
    loop.submit([1, 2, 3])
    loop.submit([4, 5])
    loop.submit([6])          # queued: only 2 slots — back-pressure
    for _ in range(3):
        live = loop.step()
    assert len(loop.outputs) >= 2
    lens = sorted(len(v) for v in loop.outputs.values())
    assert lens[-1] >= 3      # first request has prefill token + 3 decodes


def test_slot_eviction_backfills():
    cfg = registry.get_smoke("qwen1_5_0_5b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    # eos_id chosen so sequences finish quickly with an untrained model
    loop = ServeLoop(model=model, params=params, par=Parallel.local(),
                     num_slots=1, cache_len=16, eos_id=-1)
    loop.submit([1, 2])
    loop.step()
    uid0 = [u for u in loop.outputs][0]
    # force eviction by hitting cache limit
    for _ in range(16):
        loop.step()
    loop.submit([3, 4])
    loop.step()
    assert len(loop.outputs) >= 2, loop.outputs
    assert any(u != uid0 for u in loop.outputs)
