"""Serving substrate: continuous batching loop on a smoke model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.model import build_model
from repro.models.transformer import Parallel
from repro.train.serve_step import ServeLoop, make_decode_step, make_prefill


def test_decode_step_greedy():
    cfg = registry.get_smoke("qwen1_5_0_5b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prefill = make_prefill(model, Parallel.local(), 64)
    logits, caches = prefill(params, {"tokens": jnp.ones((2, 8), jnp.int32)})
    step = jax.jit(make_decode_step(model, Parallel.local()))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    pos = jnp.full((2,), 8, jnp.int32)
    for _ in range(4):
        tok, pos, caches = step(params, tok, pos, caches)
        assert tok.shape == (2, 1)
        assert bool((tok >= 0).all()) and bool((tok < cfg.padded_vocab).all())
    assert int(pos[0]) == 12


def test_serve_loop_continuous_batching():
    cfg = registry.get_smoke("qwen1_5_0_5b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    loop = ServeLoop(model=model, params=params, par=Parallel.local(),
                     num_slots=2, cache_len=32, eos_id=-1)  # never EOS
    loop.submit([1, 2, 3])
    loop.submit([4, 5])
    loop.submit([6])          # queued: only 2 slots — back-pressure
    for _ in range(3):
        live = loop.step()
    assert len(loop.outputs) >= 2
    lens = sorted(len(v) for v in loop.outputs.values())
    assert lens[-1] >= 3      # first request has prefill token + 3 decodes


def test_slot_eviction_backfills():
    cfg = registry.get_smoke("qwen1_5_0_5b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    # eos_id chosen so sequences finish quickly with an untrained model
    loop = ServeLoop(model=model, params=params, par=Parallel.local(),
                     num_slots=1, cache_len=16, eos_id=-1)
    loop.submit([1, 2])
    loop.step()
    uid0 = [u for u in loop.outputs][0]
    # force eviction by hitting cache limit
    for _ in range(16):
        loop.step()
    loop.submit([3, 4])
    loop.step()
    assert len(loop.outputs) >= 2, loop.outputs
    assert any(u != uid0 for u in loop.outputs)


# ======================================================================
# TrackingService — async admission, backpressure, circuit breaker, and
# crash-exact checkpoint/restore over the StreamScheduler (DESIGN.md §11).
import asyncio

import pytest

from repro.core.sort import SortConfig, SortEngine
from repro.serve import (CircuitBreaker, Overloaded, StreamScheduler,
                         TokenBucket, TrackingService)

MAX_DETS = 7


class FakeClock:
    """Injectable monotonic time: rate limits and breaker timeouts are
    deterministic under test."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _scenes(lengths, seed=3):
    from repro.data.synthetic import SceneConfig, generate_scene
    out = []
    for i, f in enumerate(lengths):
        _, _, db, dm = generate_scene(SceneConfig(
            num_frames=f, max_objects=4, seed=seed + i))
        d = db.shape[1]
        assert d <= MAX_DETS, d
        db = np.pad(db, ((0, 0), (0, MAX_DETS - d), (0, 0)))
        dm = np.pad(dm, ((0, 0), (0, MAX_DETS - d)))
        out.append((f"seq{i}", db, dm))
    return out


def _sched(use_kernels=False, assoc="hungarian", chunk=8, lanes=2):
    eng = SortEngine(SortConfig(max_trackers=8, max_detections=MAX_DETS,
                                use_kernels=use_kernels, assoc=assoc))
    return StreamScheduler(eng, num_lanes=lanes, max_dets=MAX_DETS,
                           chunk=chunk)


def _run(coro):
    return asyncio.run(coro)


async def _serve_all(svc, seqs):
    for s in seqs:
        await svc.submit(*s)
    await svc.drain()
    return dict(svc.completed)


def _assert_completed_equal(got, ref):
    assert sorted(got) == sorted(ref)
    for i in ref:
        assert got[i].name == ref[i].name
        np.testing.assert_array_equal(got[i].boxes, ref[i].boxes)
        np.testing.assert_array_equal(got[i].uid, ref[i].uid)
        np.testing.assert_array_equal(got[i].emit, ref[i].emit)


# ----------------------------------------------------------- token bucket
def test_token_bucket_refills_and_hints():
    clk = FakeClock()
    b = TokenBucket(rate=2.0, burst=2.0, clock=clk)
    assert b.try_take() == 0.0 and b.try_take() == 0.0
    wait = b.try_take()
    assert wait == pytest.approx(0.5)       # 1 token at 2/s
    clk.advance(0.5)
    assert b.try_take() == 0.0


def test_token_bucket_rejects_bad_params():
    with pytest.raises(ValueError):
        TokenBucket(rate=0, burst=1)


# -------------------------------------------------------- circuit breaker
def test_breaker_open_halfopen_close_cycle():
    clk = FakeClock()
    br = CircuitBreaker(failure_threshold=2, reset_timeout=5.0, clock=clk)
    assert br.allow()
    br.record_failure()
    assert br.state == CircuitBreaker.CLOSED and br.allow()
    br.record_failure()                      # threshold: opens
    assert br.state == CircuitBreaker.OPEN
    assert not br.allow()
    assert br.retry_after() == pytest.approx(5.0)
    clk.advance(5.0)
    assert br.allow()                        # the half-open probe
    assert br.state == CircuitBreaker.HALF_OPEN
    br.record_failure()                      # probe fails: re-open
    assert br.state == CircuitBreaker.OPEN and not br.allow()
    clk.advance(5.0)
    assert br.allow()
    br.record_success()                      # probe succeeds: close
    assert br.state == CircuitBreaker.CLOSED and br.failures == 0


# ------------------------------------------------------- admission bounds
def test_rate_limit_sheds_then_recovers():
    clk = FakeClock()
    seqs = _scenes([10, 10, 10])

    async def go():
        svc = TrackingService(_sched(), rate=1.0, burst=1.0, clock=clk)
        await svc.submit(*seqs[0])
        with pytest.raises(Overloaded) as ei:
            await svc.submit(*seqs[1])
        assert ei.value.reason == "rate" and ei.value.retry_after > 0
        clk.advance(ei.value.retry_after)    # honour the hint: admitted
        await svc.submit(*seqs[1])
        assert [c for c, r, _ in svc.sheds] == ["default"]
        await svc.drain()
        assert sorted(svc.completed) == [0, 1]
    _run(go())


def test_queue_bounds_shed_and_never_grow():
    seqs = _scenes([10] * 6)

    async def go():
        svc = TrackingService(_sched(), max_pending=3, per_client_pending=2)
        await svc.submit(*seqs[0], client="a")
        await svc.submit(*seqs[1], client="a")
        with pytest.raises(Overloaded) as ei:    # per-client cap first
            await svc.submit(*seqs[2], client="a")
        assert ei.value.reason == "client_queue"
        await svc.submit(*seqs[2], client="b")
        with pytest.raises(Overloaded) as ei:    # then the global cap
            await svc.submit(*seqs[3], client="c")
        assert ei.value.reason == "queue" and ei.value.retry_after > 0
        assert svc.pending == 3                  # bound held
        await svc.drain()
        assert svc.pending == 0                  # drained: admissible again
        await svc.submit(*seqs[3], client="c")
        await svc.drain()
    _run(go())


def test_zero_frame_sequence_through_service():
    """A zero-frame sequence finalizes at submit time; the service must
    deliver it (in order) without a single chunk dispatch."""
    db = np.zeros((0, MAX_DETS, 4), np.float32)
    dm = np.zeros((0, MAX_DETS), bool)

    async def go():
        svc = TrackingService(_sched())
        idx = await svc.submit("empty", db, dm)
        assert idx in svc.completed
        assert svc.completed[idx].num_frames == 0
        assert (await svc.result(idx)).name == "empty"
        assert svc.pending == 0
    _run(go())


# ------------------------------------------ breaker around real dispatch
def test_breaker_opens_sheds_probes_and_recovers(tmp_path, monkeypatch):
    """Injected chunk failures open the breaker (submissions and steps
    shed fast), the timed half-open probe retries, and — because the
    failed dispatches rolled back to the last committed checkpoint — the
    recovered run's outputs are bit-identical to an undisturbed one."""
    clk = FakeClock()
    seqs = _scenes([20, 15, 10])
    ref = _run(_serve_all(TrackingService(_sched()), seqs))

    async def go():
        sched = _sched()
        svc = TrackingService(sched, ckpt_dir=str(tmp_path),
                              breaker_threshold=2, breaker_reset=5.0,
                              clock=clk)
        for s in seqs:
            await svc.submit(*s)
        svc.checkpoint(wait=True)
        real = sched.run_chunk        # bound before patching

        def boom():
            raise RuntimeError("injected device failure")
        monkeypatch.setattr(sched, "run_chunk", boom)
        for _ in range(2):            # threshold failures -> OPEN
            with pytest.raises(RuntimeError):
                await svc.step()
        assert svc.breaker.state == CircuitBreaker.OPEN
        with pytest.raises(Overloaded) as ei:     # fast-shed both paths
            await svc.step()
        assert ei.value.reason == "breaker_open"
        with pytest.raises(Overloaded):
            await svc.submit("late", seqs[0][1], seqs[0][2])
        monkeypatch.setattr(sched, "run_chunk", real)
        clk.advance(5.0)              # half-open probe allowed, succeeds
        await svc.step()
        assert svc.breaker.state == CircuitBreaker.CLOSED
        await svc.drain()
        svc.close()
        return dict(svc.completed)

    _assert_completed_equal(_run(go()), ref)


def test_rollback_without_checkpoint_is_noop(monkeypatch):
    sched = _sched()
    seqs = _scenes([10])

    async def go():
        svc = TrackingService(sched, breaker_threshold=1)
        await svc.submit(*seqs[0])

        def boom():
            raise RuntimeError("no ckpt to roll back to")
        monkeypatch.setattr(sched, "run_chunk", boom)
        with pytest.raises(RuntimeError):
            await svc.step()
        assert svc.breaker.state == CircuitBreaker.OPEN
    _run(go())


# -------------------------------------------- crash-exact resume (tentpole)
@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("assoc", ["hungarian", "greedy"])
def test_kill_and_resume_bit_identical(tmp_path, use_kernels, assoc):
    """The acceptance bar: SIGKILL mid-serve (simulated by abandoning the
    service object after some chunks), resume from the latest committed
    checkpoint, and every sequence's tracks come out bit-identical to an
    uninterrupted run — on both engine paths and both association modes."""
    seqs = _scenes([17, 30, 9, 23, 12])
    ref = _run(_serve_all(
        TrackingService(_sched(use_kernels, assoc)), seqs))

    async def crash():
        svc = TrackingService(_sched(use_kernels, assoc),
                              ckpt_dir=str(tmp_path), ckpt_every=1)
        for s in seqs:
            await svc.submit(*s)
        svc.checkpoint(wait=True)
        for _ in range(3):
            await svc.step()
        svc.close()                   # flush; then the process "dies"
        return dict(svc.completed)

    async def resume():
        svc = TrackingService.resume(_sched(use_kernels, assoc),
                                     str(tmp_path))
        await svc.drain()
        svc.close()
        return dict(svc.completed)

    before = _run(crash())
    after = _run(resume())
    got = dict(before)
    got.update(after)                 # union covers every sequence
    _assert_completed_equal(got, ref)
    # at-least-once: anything the resumed run re-delivered is bit-equal
    for i in set(before) & set(after):
        np.testing.assert_array_equal(before[i].boxes, after[i].boxes)


def test_resume_lands_on_last_committed_step(tmp_path):
    """Chunks dispatched AFTER the last committed checkpoint are lost to
    the crash; resume must redo them — never skip, never double-advance
    device state."""
    seqs = _scenes([25, 18])
    ref = _run(_serve_all(TrackingService(_sched()), seqs))

    async def crash():
        svc = TrackingService(_sched(), ckpt_dir=str(tmp_path),
                              ckpt_every=100)   # only the manual ckpt
        for s in seqs:
            await svc.submit(*s)
        svc.checkpoint(wait=True)               # committed: step 0
        for _ in range(2):                      # ...then uncovered work
            await svc.step()

    async def resume():
        svc = TrackingService.resume(_sched(), str(tmp_path))
        assert svc.sched.chunks_run == 0        # back at the commit point
        await svc.drain()
        svc.close()
        return dict(svc.completed)

    _run(crash())
    _assert_completed_equal(_run(resume()), ref)


def test_resume_across_engine_paths(tmp_path):
    """Checkpoints are execution-strategy-neutral: save under the
    per-phase engine, resume under the fused kernel path.  The two paths
    agree to float tolerance, not bit-for-bit (tests/test_oracle_parity
    compares them with allclose), so the cross-path resume contract is:
    track identities and lifecycle exact, coordinates allclose.  Same-
    strategy resume is bit-exact (test_kill_and_resume_bit_identical)."""
    seqs = _scenes([14, 21, 8])
    ref = _run(_serve_all(TrackingService(_sched(use_kernels=True)), seqs))

    async def crash():
        svc = TrackingService(_sched(use_kernels=False),
                              ckpt_dir=str(tmp_path))
        for s in seqs:
            await svc.submit(*s)
        svc.checkpoint(wait=True)
        await svc.step()
        svc.close()
        return dict(svc.completed)

    async def resume():
        svc = TrackingService.resume(_sched(use_kernels=True),
                                     str(tmp_path))
        await svc.drain()
        svc.close()
        return dict(svc.completed)

    before = _run(crash())
    got = dict(before)
    got.update(_run(resume()))
    assert sorted(got) == sorted(ref)
    for i in ref:
        assert got[i].name == ref[i].name
        np.testing.assert_array_equal(got[i].uid, ref[i].uid)
        np.testing.assert_array_equal(got[i].emit, ref[i].emit)
        np.testing.assert_allclose(got[i].boxes, ref[i].boxes,
                                   rtol=1e-3, atol=1e-2)


def test_resume_rejects_non_service_checkpoint(tmp_path):
    from repro.ckpt import save
    save(str(tmp_path), 1, {"w": np.zeros(3)})
    with pytest.raises(ValueError, match="service metadata"):
        TrackingService.resume(_sched(), str(tmp_path))


def test_service_checkpoint_write_failure_raises(tmp_path, monkeypatch):
    """An injected checkpoint-write failure must surface through the
    service (close()/next checkpoint), never pass as a committed save."""
    from repro.ckpt import checkpoint as ck
    seqs = _scenes([12])

    async def go():
        svc = TrackingService(_sched(), ckpt_dir=str(tmp_path))
        await svc.submit(*seqs[0])

        def boom(*a, **k):
            raise OSError("injected write failure")
        monkeypatch.setattr(ck, "save", boom)
        svc.checkpoint()                 # async: failure lands in-thread
        with pytest.raises(OSError, match="injected write failure"):
            svc.close()
    _run(go())
