"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bbox, kalman
from repro.kernels import iou_cost, kalman_fused, ops, ref


def _spd(rng, shape):
    a = rng.normal(size=shape + (7, 7)).astype(np.float32)
    return a @ a.swapaxes(-1, -2) + 0.5 * np.eye(7, dtype=np.float32)


@pytest.mark.parametrize("s,t,block", [(1, 8, 8), (3, 8, 16), (2, 16, 32),
                                       (5, 7, 64)])
def test_predict_kernel_sweep(s, t, block):
    rng = np.random.default_rng(s * 100 + t)
    x = jnp.asarray(rng.normal(size=(s, t, 7)).astype(np.float32))
    p = jnp.asarray(_spd(rng, (s, t)))
    xk, pk = ops.predict(x, p, block_b=block, interpret=True)
    params = kalman.KalmanParams.default()
    xr, pr = kalman.predict(x, p, params)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("s,t,block", [(1, 8, 8), (4, 8, 16), (2, 16, 64)])
def test_update_kernel_sweep(s, t, block):
    rng = np.random.default_rng(s * 10 + t)
    x = jnp.asarray(rng.normal(size=(s, t, 7)).astype(np.float32))
    p = jnp.asarray(_spd(rng, (s, t)))
    z = jnp.asarray(rng.normal(size=(s, t, 4)).astype(np.float32) * 5)
    m = jnp.asarray(rng.random((s, t)) < 0.6)
    xk, pk = ops.update(x, p, z, m, block_b=block, interpret=True)
    params = kalman.KalmanParams.default()
    xr, pr = kalman.masked_update(x, p, z, m, params)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-3,
                               atol=1e-3)


@pytest.mark.parametrize("s,d,t,block", [(1, 4, 4, 8), (8, 6, 5, 8),
                                         (16, 16, 16, 16)])
def test_iou_kernel_sweep(s, d, t, block):
    rng = np.random.default_rng(d * 10 + t)

    def boxes(shape):
        xy = rng.uniform(0, 200, size=shape + (2,))
        wh = rng.uniform(5, 100, size=shape + (2,))
        return jnp.asarray(np.concatenate([xy, xy + wh], -1)
                           .astype(np.float32))

    det = boxes((s, d))
    trk = boxes((s, t))
    got = ops.iou(det, trk, block_b=block, interpret=True)
    want = bbox.iou_matrix(det, trk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_fused_step_kernel():
    rng = np.random.default_rng(0)
    b = 64
    x = jnp.asarray(rng.normal(size=(7, b)).astype(np.float32))
    p = jnp.asarray(_spd(rng, (b,)).reshape(b, 49).T.copy())
    z = jnp.asarray(rng.normal(size=(4, b)).astype(np.float32))
    m = jnp.asarray((rng.random((1, b)) < 0.5).astype(np.float32))
    xk, pk = kalman_fused.fused_step(x, p, z, m, block_b=32, interpret=True)
    xr, pr = ref.predict_lane(x, p)
    xr, pr = ref.update_lane(xr, pr, z, m)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-3,
                               atol=1e-3)


def test_lane_layout_roundtrip():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(3, 5, 7)).astype(np.float32))
    p = jnp.asarray(_spd(rng, (3, 5)))
    xl, pl_ = ops.to_lane(x, p, 64)
    assert xl.shape == (7, 64) and pl_.shape == (49, 64)
    x2, p2 = ops.from_lane(xl, pl_, 3, 5)
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))


def test_engine_with_kernels_equals_reference_engine():
    from repro.core import SortConfig, SortEngine
    from repro.data.synthetic import SceneConfig, generate_scene
    cfg = SceneConfig(num_frames=25, max_objects=6, seed=9)
    _, _, det_boxes, det_mask = generate_scene(cfg)
    d = det_boxes.shape[1]
    pf, uf, jf = ops.engine_fns(use_ref=True)
    eng_k = SortEngine(SortConfig(max_trackers=16, max_detections=d),
                       predict_fn=pf, update_fn=uf, iou_fn=jf)
    eng_r = SortEngine(SortConfig(max_trackers=16, max_detections=d))
    db = jnp.asarray(det_boxes[:, None])
    dm = jnp.asarray(det_mask[:, None])
    _, out_k = jax.jit(eng_k.run)(eng_k.init(1), db, dm)
    _, out_r = jax.jit(eng_r.run)(eng_r.init(1), db, dm)
    np.testing.assert_array_equal(np.asarray(out_k.uid),
                                  np.asarray(out_r.uid))
    np.testing.assert_allclose(np.asarray(out_k.boxes),
                               np.asarray(out_r.boxes), rtol=1e-3, atol=1e-2)
