"""Elastic restart: checkpoint saved under one topology restores onto a
different mesh (subprocess: device count is fixed at jax init)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt import checkpoint as ck
    from repro.launch.mesh import make_mesh
    from repro.models.config import ModelConfig
    from repro.models.model import build_model
    from repro.sharding.rules import params_pspecs

    tmp = sys.argv[1]
    cfg = ModelConfig(num_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                      d_ff=64, vocab_size=128, max_seq_len=32,
                      dtype="float32")
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))

    # save under a 4x2 mesh ("two pods")
    mesh_a = make_mesh((4, 2), ("data", "model"))
    sh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s),
                        params_pspecs(specs, params, mesh_a),
                        is_leaf=lambda x: isinstance(x, P))
    params_a = jax.device_put(params, sh_a)
    ck.save(tmp, 5, jax.device_get(params_a), logical_specs=specs)

    # "lose a pod": restore onto a 2x2 mesh with re-derived shardings
    mesh_b = make_mesh((2, 2), ("data", "model"))
    ps_b = params_pspecs(specs, params, mesh_b)
    restored, step = ck.restore(tmp, params, mesh=mesh_b, pspecs=ps_b)
    assert step == 5
    # values identical, now placed for the smaller mesh
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the restored tree is usable: loss computes under mesh_b
    batch = {"tokens": jnp.ones((4, 8), jnp.int32),
             "labels": jnp.ones((4, 8), jnp.int32)}
    loss = float(jax.jit(model.loss)(restored, batch))
    print(json.dumps({"ok": True, "loss": loss, "step": step}))
""")


@pytest.mark.slow
def test_elastic_restore_different_mesh(tmp_path):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path)], capture_output=True,
        text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["step"] == 5
