"""Slot-pool lifecycle invariants (shared by trackers and serving)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import slots


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 16), st.integers(0, 16))
def test_assign_slots_valid_matching(seed, t, d):
    rng = np.random.default_rng(seed)
    free = jnp.asarray(rng.random(t) < 0.5)
    want = jnp.asarray(rng.random(max(d, 1)) < 0.5)
    slot_for = np.asarray(slots.assign_slots(free, want))
    claimed = slot_for[slot_for >= 0]
    # distinct slots, all actually free, count = min(#want, #free)
    assert len(set(claimed.tolist())) == len(claimed)
    assert all(bool(free[s]) for s in claimed)
    assert len(claimed) == min(int(np.asarray(want).sum()),
                               int(np.asarray(free).sum()))
    # non-wanting claimants get -1
    for i, w in enumerate(np.asarray(want)):
        if not w:
            assert slot_for[i] == -1


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lifecycle_birth_tick_kill(seed):
    rng = np.random.default_rng(seed)
    pool = slots.init_pool((), 8)
    uids_seen = set()
    for step in range(20):
        want = jnp.asarray(rng.random(4) < 0.4)
        slot_for = slots.assign_slots(~pool.alive, want)
        pool = slots.birth(pool, slot_for)
        alive = np.asarray(pool.alive)
        uid = np.asarray(pool.uid)
        # uids unique among alive
        live_uids = uid[alive].tolist()
        assert len(set(live_uids)) == len(live_uids)
        uids_seen.update(live_uids)
        matched = jnp.asarray(rng.random(8) < 0.6) & pool.alive
        pool = slots.tick(pool, matched, max_age=1)
        tsu = np.asarray(pool.time_since_update)
        assert (tsu[np.asarray(pool.alive)] <= 1).all()
        assert (np.asarray(pool.uid)[~np.asarray(pool.alive)] == -1).all()
    assert len(uids_seen) >= 1


def test_uid_monotonicity():
    pool = slots.init_pool((), 4)
    slot_for = slots.assign_slots(~pool.alive, jnp.asarray([True, True]))
    pool = slots.birth(pool, slot_for)
    first = sorted(np.asarray(pool.uid)[np.asarray(pool.alive)].tolist())
    assert first == [1, 2]
    pool = slots.tick(pool, jnp.zeros(4, bool), max_age=0)  # kill all
    slot_for = slots.assign_slots(~pool.alive, jnp.asarray([True]))
    pool = slots.birth(pool, slot_for)
    assert sorted(np.asarray(pool.uid)[np.asarray(pool.alive)].tolist()) == [3]


def test_overflow_drops_claims():
    pool = slots.init_pool((), 2)
    slot_for = slots.assign_slots(~pool.alive,
                                  jnp.asarray([True, True, True, True]))
    assert (np.asarray(slot_for) >= 0).sum() == 2
    pool = slots.birth(pool, slot_for)
    assert int(pool.num_alive) == 2
