"""Optimizer + train-step substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (AdamWConfig, adamw, apply_updates,
                                   clip_by_global_norm, cosine_schedule)
from repro.train.train_step import init_state, make_train_step
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.models.transformer import Parallel


def test_adamw_converges_quadratic():
    opt_cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, min_lr_ratio=1.0)
    init, update = adamw(opt_cfg)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        updates, state, _ = update(grads, state, params)
        params = apply_updates(params, updates)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 100
    total = float(jnp.sqrt(sum(jnp.sum(l ** 2)
                               for l in jax.tree.leaves(clipped))))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    sched = cosine_schedule(cfg)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 0.11
    assert float(sched(jnp.asarray(55))) < float(sched(jnp.asarray(20)))


def _tiny():
    cfg = ModelConfig(num_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab_size=64, max_seq_len=32,
                      dtype="float32")
    return cfg, build_model(cfg)


def test_loss_decreases():
    cfg, model = _tiny()
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=60)
    step = jax.jit(make_train_step(model, Parallel.local(), opt_cfg))
    state = init_state(params, opt_cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, (4, 33))
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}  # fixed batch: memorize it
    losses = []
    for _ in range(40):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_microbatch_equivalence():
    """Grad accumulation must equal the monolithic step (same data)."""
    cfg, model = _tiny()
    params, _ = model.init(jax.random.PRNGKey(1))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 64, (8, 33))
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    s1 = init_state(params, opt_cfg)
    s2 = init_state(params, opt_cfg)
    step1 = jax.jit(make_train_step(model, Parallel.local(), opt_cfg,
                                    microbatches=1))
    step2 = jax.jit(make_train_step(model, Parallel.local(), opt_cfg,
                                    microbatches=4))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


def test_train_driver_end_to_end(tmp_path):
    """The launch driver: train, checkpoint, resume — losses keep improving."""
    from repro.launch.train import main
    loss1 = main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps", "30",
                  "--batch", "4", "--seq", "32", "--lr", "5e-3",
                  "--ckpt-dir", str(tmp_path), "--ckpt-every", "15"])
    # resume from step 30 checkpoint and continue to 45
    loss2 = main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps", "45",
                  "--batch", "4", "--seq", "32", "--lr", "5e-3",
                  "--ckpt-dir", str(tmp_path), "--resume"])
    assert np.isfinite(loss1) and np.isfinite(loss2)
