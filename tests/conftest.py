import os

# Tests run single-device (the dry-run owns the 512-device configuration);
# multi-device integration tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
