"""Greedy associator: matching validity + relation to Hungarian optimum."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.greedy import greedy_assign


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 8))
def test_greedy_is_valid_matching(seed, d, t):
    rng = np.random.default_rng(seed)
    iou = jnp.asarray(rng.random((d, t)).astype(np.float32))
    dm = jnp.asarray(rng.random(d) < 0.8)
    tm = jnp.asarray(rng.random(t) < 0.8)
    out = np.asarray(greedy_assign(iou, dm, tm, 0.3))
    matched = out[out >= 0]
    assert len(set(matched.tolist())) == len(matched)  # injective
    for i, j in enumerate(out):
        if j >= 0:
            assert bool(dm[i]) and bool(tm[j])
            assert float(iou[i, j]) >= 0.3


def test_greedy_picks_best_first():
    iou = jnp.asarray([[0.9, 0.8], [0.85, 0.1]], jnp.float32)
    out = np.asarray(greedy_assign(iou, jnp.ones(2, bool),
                                   jnp.ones(2, bool), 0.3))
    # greedy: (0,0)=0.9 first, then (1,?) only 0.1 left -> unmatched
    # (hungarian would pick (0,1)+(1,0) = 1.65 total)
    assert out[0] == 0 and out[1] == -1


def test_greedy_matches_hungarian_on_unambiguous():
    rng = np.random.default_rng(0)
    from repro.core import association
    for _ in range(10):
        # well-separated diagonal-dominant IoU: both solvers must agree
        base = np.eye(6) * 0.9 + rng.random((6, 6)) * 0.05
        iou = jnp.asarray(base.astype(np.float32))
        g = np.asarray(greedy_assign(iou, jnp.ones(6, bool),
                                     jnp.ones(6, bool), 0.3))
        np.testing.assert_array_equal(g, np.arange(6))


def test_greedy_batched():
    rng = np.random.default_rng(1)
    iou = jnp.asarray(rng.random((4, 5, 5)).astype(np.float32))
    out = np.asarray(greedy_assign(iou, jnp.ones((4, 5), bool),
                                   jnp.ones((4, 5), bool), 0.0))
    for b in range(4):
        m = out[b][out[b] >= 0]
        assert len(set(m.tolist())) == len(m)
