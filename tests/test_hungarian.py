"""Hungarian solver vs scipy — exact optimal cost on every instance."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from scipy.optimize import linear_sum_assignment

from repro.core import hungarian


def _total(cost, col4row, c_valid):
    total, cnt = 0.0, 0
    for i in range(cost.shape[0]):
        j = int(col4row[i])
        if j < c_valid:
            total += cost[i, j]
            cnt += 1
    return total, cnt


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 10), st.integers(0, 2**31 - 1),
       st.sampled_from([0.01, 1.0, 100.0]))
def test_square_matches_scipy(n, seed, scale):
    rng = np.random.default_rng(seed)
    cost = (rng.normal(size=(n, n)) * scale).astype(np.float32)
    col4row = np.asarray(hungarian.solve(jnp.asarray(cost)))
    assert sorted(col4row.tolist()) == list(range(n)), "not a permutation"
    ours = cost[np.arange(n), col4row].sum()
    ri, ci = linear_sum_assignment(cost)
    np.testing.assert_allclose(ours, cost[ri, ci].sum(), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_rectangular_masked(r, c, seed):
    rng = np.random.default_rng(seed)
    n = max(r, c) + int(rng.integers(0, 4))
    cost = rng.normal(size=(r, c)).astype(np.float32)
    col4row = np.asarray(hungarian.solve_masked(
        jnp.asarray(cost), jnp.ones(r, bool), jnp.ones(c, bool), n))
    total, cnt = _total(cost, col4row, c)
    ri, ci = linear_sum_assignment(cost)
    assert cnt == min(r, c)
    np.testing.assert_allclose(total, cost[ri, ci].sum(), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_partial_masks(seed):
    rng = np.random.default_rng(seed)
    n = 10
    cost = rng.normal(size=(n, n)).astype(np.float32)
    rm = rng.random(n) < 0.7
    cm = rng.random(n) < 0.7
    if rm.sum() == 0 or cm.sum() == 0:
        return
    col4row = np.asarray(hungarian.solve_masked(
        jnp.asarray(cost), jnp.asarray(rm), jnp.asarray(cm), n))
    sub = cost[np.ix_(rm, cm)]
    ri, ci = linear_sum_assignment(sub)
    rows, cols = np.where(rm)[0], set(np.where(cm)[0].tolist())
    total = sum(cost[i, col4row[i]] for i in rows if col4row[i] in cols)
    cnt = sum(1 for i in rows if col4row[i] in cols)
    assert cnt == min(rm.sum(), cm.sum())
    np.testing.assert_allclose(total, sub[ri, ci].sum(), rtol=1e-4,
                               atol=1e-4)


def test_batched_vmap():
    rng = np.random.default_rng(3)
    cost = rng.normal(size=(5, 7, 7)).astype(np.float32)
    out = np.asarray(hungarian.solve_batched(jnp.asarray(cost)))
    for b in range(5):
        ri, ci = linear_sum_assignment(cost[b])
        ours = cost[b][np.arange(7), out[b]].sum()
        np.testing.assert_allclose(ours, cost[b][ri, ci].sum(), rtol=1e-4,
                                   atol=1e-4)


def test_ties_still_optimal():
    cost = np.zeros((4, 4), np.float32)  # fully degenerate
    col4row = np.asarray(hungarian.solve(jnp.asarray(cost)))
    assert sorted(col4row.tolist()) == [0, 1, 2, 3]


@pytest.mark.parametrize("n", [1, 2, 13, 16])
def test_identity_cost(n):
    cost = (1.0 - np.eye(n)).astype(np.float32)
    col4row = np.asarray(hungarian.solve(jnp.asarray(cost)))
    np.testing.assert_array_equal(col4row, np.arange(n))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 7), st.integers(1, 7), st.integers(1, 6),
       st.integers(0, 2**31 - 1))
def test_lane_layout_matches_batch_layout_bitwise(r, c, lanes, seed):
    """``solve_masked_lane`` (batch on the trailing lane axis, the fused
    frame step's layout) == ``solve_masked`` on the transposed batch, bit
    for bit — moving the batch axis must not change any per-problem
    decision."""
    rng = np.random.default_rng(seed)
    n = max(r, c)
    cost = rng.normal(size=(lanes, r, c)).astype(np.float32)
    rm = rng.random((lanes, r)) < 0.8
    cm = rng.random((lanes, c)) < 0.8
    want = np.asarray(hungarian.solve_masked(
        jnp.asarray(cost), jnp.asarray(rm), jnp.asarray(cm), n))
    got = np.asarray(hungarian.solve_masked_lane(
        jnp.asarray(cost.transpose(1, 2, 0)), jnp.asarray(rm.T),
        jnp.asarray(cm.T), n))
    np.testing.assert_array_equal(got.T, want)


def test_lane_layout_multi_lane_axes():
    """solve_masked_lane flattens arbitrary trailing lane axes."""
    rng = np.random.default_rng(11)
    r = c = n = 4
    cost = rng.normal(size=(r, c, 2, 3)).astype(np.float32)
    rm = np.ones((r, 2, 3), bool)
    cm = np.ones((c, 2, 3), bool)
    out = np.asarray(hungarian.solve_masked_lane(
        jnp.asarray(cost), jnp.asarray(rm), jnp.asarray(cm), n))
    assert out.shape == (n, 2, 3)
    for i in range(2):
        for j in range(3):
            ri, ci = linear_sum_assignment(cost[:, :, i, j])
            ours = cost[np.arange(r), out[:, i, j], i, j].sum()
            np.testing.assert_allclose(
                ours, cost[:, :, i, j][ri, ci].sum(), rtol=1e-4, atol=1e-4)
