"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (per spec)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_state, make_train_step
from repro.models.transformer import Parallel


def _smoke_batch(cfg, rng, b=2, l=16):
    if cfg.modality == "audio":
        return {"feats": jnp.asarray(rng.normal(size=(b, l, cfg.d_model))
                                     .astype(np.float32)),
                "mask_spans": jnp.asarray(rng.random((b, l)) < 0.2),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   (b, l)), dtype=jnp.int32),
                "loss_mask": jnp.ones((b, l), jnp.float32)}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, l)),
                                   dtype=jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, l)),
                                   dtype=jnp.int32)}
    if cfg.modality == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.frontend_dim))
            .astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.get_smoke(arch)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _smoke_batch(cfg, rng)

    logits = jax.jit(model.forward)(params, batch)
    exp_len = 16 + (cfg.num_patches if cfg.modality == "vision" else 0)
    assert logits.shape == (2, exp_len, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = jax.jit(make_train_step(model, Parallel.local(),
                                   AdamWConfig(lr=1e-3, warmup_steps=1,
                                               total_steps=10)))
    state = init_state(params, AdamWConfig())
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"])), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(m["grad_norm"])), f"{arch}: non-finite grads"
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """Guard the published numbers (layer count, width, heads, vocab)."""
    cfg = registry.get_arch(arch)
    expected = {
        "qwen1_5_0_5b": (24, 1024, 16, 151936),
        "qwen2_7b": (28, 3584, 28, 152064),
        "minicpm3_4b": (62, 2560, 40, 73448),
        "qwen2_5_14b": (48, 5120, 40, 152064),
        "deepseek_v2_236b": (60, 5120, 128, 102400),
        "deepseek_v2_lite_16b": (27, 2048, 16, 102400),
        "hubert_xlarge": (48, 1280, 16, 504),
        "mamba2_2_7b": (64, 2560, 0, 50280),
        "llava_next_mistral_7b": (32, 4096, 32, 32000),
        "hymba_1_5b": (32, 1600, 25, 32001),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.n_heads,
            cfg.vocab_size) == expected


def test_deepseek_moe_structure():
    cfg = registry.get_arch("deepseek_v2_236b")
    assert cfg.moe and cfg.n_routed_experts == 160
    assert cfg.n_shared_experts == 2 and cfg.moe_top_k == 6
    assert cfg.first_k_dense == 1 and cfg.kv_lora_rank == 512


def test_param_counts_in_range():
    """Analytic parameter counts should land near the advertised sizes."""
    targets = {"qwen2_7b": 7.6e9, "qwen2_5_14b": 14.8e9,
               "deepseek_v2_236b": 236e9, "mamba2_2_7b": 2.7e9,
               "llava_next_mistral_7b": 7.2e9}
    for arch, t in targets.items():
        n = registry.get_arch(arch).num_params()
        assert abs(n - t) / t < 0.08, (arch, n, t)
