"""End-to-end SORT: batched JAX engine == per-stream numpy reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SortConfig, SortEngine, metrics
from repro.core.ref_numpy import Sort as RefSort
from repro.data.synthetic import SceneConfig, generate_scene


def _run_ref(det_boxes, det_mask):
    ref = RefSort()
    out = []
    for t in range(det_boxes.shape[0]):
        out.append(ref.update(det_boxes[t][det_mask[t]]))
    return out


def _run_engine(det_boxes, det_mask, n_copies=1):
    f, d = det_boxes.shape[:2]
    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d))
    state = eng.init(n_copies)
    db = jnp.asarray(np.repeat(det_boxes[:, None], n_copies, 1))
    dm = jnp.asarray(np.repeat(det_mask[:, None], n_copies, 1))
    _, out = jax.jit(eng.run)(state, db, dm)
    return out


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_engine_matches_reference(seed):
    cfg = SceneConfig(num_frames=60, max_objects=8, seed=seed)
    _, _, det_boxes, det_mask = generate_scene(cfg)
    ref_out = _run_ref(det_boxes, det_mask)
    out = _run_engine(det_boxes, det_mask)
    for t in range(det_boxes.shape[0]):
        em = np.asarray(out.emit[t, 0])
        ids_ours = sorted(int(u) for u in np.asarray(out.uid[t, 0])[em])
        ids_ref = sorted(int(o[4]) for o in ref_out[t])
        assert ids_ours == ids_ref, f"frame {t}"
        boxes_ours = {int(u): np.asarray(out.boxes[t, 0, k])
                      for k, u in enumerate(np.asarray(out.uid[t, 0]))
                      if em[k]}
        for o in ref_out[t]:
            np.testing.assert_allclose(boxes_ours[int(o[4])], o[:4],
                                       rtol=1e-3, atol=0.5)


def test_streams_are_independent():
    """Paper's premise: throughput lanes don't interact."""
    cfg_a = SceneConfig(num_frames=40, max_objects=6, seed=1)
    cfg_b = SceneConfig(num_frames=40, max_objects=6, seed=2)
    _, _, db_a, dm_a = generate_scene(cfg_a)
    _, _, db_b, dm_b = generate_scene(cfg_b)
    d = max(db_a.shape[1], db_b.shape[1])

    def pad(db, dm):
        out_b = np.zeros((40, d, 4), np.float32)
        out_m = np.zeros((40, d), bool)
        out_b[:, :db.shape[1]] = db
        out_m[:, :dm.shape[1]] = dm
        return out_b, out_m

    db_a, dm_a = pad(db_a, dm_a)
    db_b, dm_b = pad(db_b, dm_b)
    solo = _run_engine(db_a, dm_a)

    eng = SortEngine(SortConfig(max_trackers=16, max_detections=d))
    state = eng.init(2)
    db = jnp.asarray(np.stack([db_a, db_b], 1))
    dm = jnp.asarray(np.stack([dm_a, dm_b], 1))
    _, joint = jax.jit(eng.run)(state, db, dm)
    np.testing.assert_allclose(np.asarray(joint.boxes[:, 0]),
                               np.asarray(solo.boxes[:, 0]), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_array_equal(np.asarray(joint.uid[:, 0]),
                                  np.asarray(solo.uid[:, 0]))


def test_tracking_quality_mota():
    """With mild noise the tracker should stay close to ground truth."""
    cfg = SceneConfig(num_frames=120, max_objects=8, seed=5,
                      miss_rate=0.02, fp_rate=0.05, det_noise=1.0)
    gt_boxes, gt_mask, det_boxes, det_mask = generate_scene(cfg)
    out = _run_engine(det_boxes, det_mask)
    m = metrics.mota(gt_boxes, gt_mask,
                     np.asarray(out.boxes[:, 0]),
                     np.asarray(out.uid[:, 0]),
                     np.asarray(out.emit[:, 0]))
    assert m["mota"] > 0.5, m
    assert m["id_switches"] < 0.05 * m["num_gt"], m


def test_masks_static_shapes_under_jit():
    """The whole step must be trace-once (no data-dependent shapes)."""
    cfg = SceneConfig(num_frames=10, max_objects=5, seed=7)
    _, _, det_boxes, det_mask = generate_scene(cfg)
    eng = SortEngine(SortConfig(max_trackers=8,
                                max_detections=det_boxes.shape[1]))
    state = eng.init(4)
    step = jax.jit(eng.step)
    compiled = step.lower(state, jnp.asarray(det_boxes[0][None].repeat(4, 0)),
                          jnp.asarray(det_mask[0][None].repeat(4, 0))).compile()
    assert compiled is not None


# ------------------------------------------------------- lifecycle audit
# Hand-stepped traces locking slots.tick + emit gating to original SORT
# semantics (kill when time_since_update > max_age; emit when updated this
# frame AND (hit_streak >= min_hits OR frame_count <= min_hits)), on both
# engine paths, cross-checked against the numpy oracle frame by frame.
# The engine resets hit_streak at the missed frame's tick where Bewley
# defers it to the next predict — representationally different, observably
# identical (emit already requires an update this frame).

_BOX = np.array([10.0, 10.0, 20.0, 20.0], np.float32)


def _step_schedule(use_kernels, present):
    """Step one stream through a present/absent detection schedule,
    returning per-frame (alive, uid, hits, hit_streak, tsu, emitted)."""
    eng = SortEngine(SortConfig(max_trackers=4, max_detections=1,
                                use_kernels=use_kernels))
    state = eng.init(1)
    rows = []
    for pres in present:
        state, out = eng.step(state, jnp.asarray(_BOX[None, None]),
                              jnp.asarray(np.array([[bool(pres)]])))
        pool = state.pool
        rows.append((bool(pool.alive[0, 0]), int(pool.uid[0, 0]),
                     int(pool.hits[0, 0]), int(pool.hit_streak[0, 0]),
                     int(pool.time_since_update[0, 0]),
                     bool(out.emit[0, 0])))
    return rows


def _ref_emits(present):
    ref = RefSort()
    out = []
    for pres in present:
        frame = ref.update(_BOX[None] if pres else np.zeros((0, 4)))
        out.append(sorted(int(o[4]) for o in frame))
    return out


@pytest.mark.parametrize("use_kernels", [False, True])
def test_lifecycle_trace_miss_revive_and_death(use_kernels):
    """One object: warm-up emits, a miss at tsu==max_age survives, the
    revived track stays silent until its streak rebuilds, and the second
    consecutive miss (tsu > max_age) kills it — frame-exact."""
    present = [1, 1, 1, 1, 0, 1, 0, 0]
    rows = _step_schedule(use_kernels, present)
    #         alive  uid hits streak tsu  emit
    assert rows == [
        (True,  1, 0, 0, 0, True),    # f1 birth; warm-up emit
        (True,  1, 1, 1, 0, True),    # f2 match; warm-up emit
        (True,  1, 2, 2, 0, True),    # f3 match; warm-up boundary (fc==min_hits)
        (True,  1, 3, 3, 0, True),    # f4 streak reaches min_hits
        (True,  1, 3, 0, 1, False),   # f5 miss: survives (tsu == max_age)
        (True,  1, 4, 1, 0, False),   # f6 re-acquired: alive but SILENT
        (True,  1, 4, 0, 1, False),   # f7 miss again: still alive
        (False, -1, 4, 0, 2, False),  # f8 tsu > max_age: killed
    ]
    # the observable emit stream must equal the numpy oracle's
    emitted = [[1] if r[5] else [] for r in rows]
    assert emitted == _ref_emits(present)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_lifecycle_trace_late_birth_has_no_warmup(use_kernels):
    """A tracker born after frame min_hits gets no warm-up: it must stay
    silent for exactly min_hits frames until its streak qualifies."""
    present = [0, 0, 0, 0, 1, 1, 1, 1]
    rows = _step_schedule(use_kernels, present)
    assert [r[5] for r in rows] == [False] * 7 + [True]  # emits only at f8
    assert rows[4] == (True, 1, 0, 0, 0, False)   # born f5, fc > min_hits
    assert rows[7] == (True, 1, 3, 3, 0, True)    # streak == min_hits
    emitted = [[1] if r[5] else [] for r in rows]
    assert emitted == _ref_emits(present)


def test_associate_zero_tracker_slots():
    """Regression: T=0 (e.g. first frame before any births) used to
    take_along_axis into a size-0 axis; now returns all-unmatched."""
    from repro.core import association

    rng = np.random.default_rng(2)
    det = jnp.asarray(rng.uniform(0, 100, (3, 4, 4)).astype(np.float32))
    dmask = jnp.asarray(rng.random((3, 4)) < 0.8)
    trk = jnp.zeros((3, 0, 4), jnp.float32)
    tmask = jnp.zeros((3, 0), bool)
    a = association.associate(det, dmask, trk, tmask, 0.3)
    assert a.trk_to_det.shape == (3, 0)
    assert a.iou.shape == (3, 4, 0)
    np.testing.assert_array_equal(np.asarray(a.det_to_trk),
                                  np.full((3, 4), -1))
    assert not np.asarray(a.matched_det).any()
    # every valid detection should seed a birth
    np.testing.assert_array_equal(np.asarray(a.unmatched_det),
                                  np.asarray(dmask))


def test_associate_zero_detections():
    """The mirror degenerate shape (D=0, an empty frame) also guards."""
    from repro.core import association

    rng = np.random.default_rng(4)
    det = jnp.zeros((2, 0, 4), jnp.float32)
    dmask = jnp.zeros((2, 0), bool)
    trk = jnp.asarray(rng.uniform(0, 100, (2, 5, 4)).astype(np.float32))
    tmask = jnp.asarray(rng.random((2, 5)) < 0.8)
    a = association.associate(det, dmask, trk, tmask, 0.3)
    assert a.det_to_trk.shape == (2, 0)
    np.testing.assert_array_equal(np.asarray(a.trk_to_det),
                                  np.full((2, 5), -1))
    # every alive tracker missed this frame
    np.testing.assert_array_equal(np.asarray(a.unmatched_trk),
                                  np.asarray(tmask))


def test_associate_zero_slots_under_jit():
    """The guard is a static-shape branch, so it must trace cleanly."""
    from repro.core import association

    det = jnp.ones((1, 2, 4), jnp.float32)
    dmask = jnp.ones((1, 2), bool)
    trk = jnp.zeros((1, 0, 4), jnp.float32)
    tmask = jnp.zeros((1, 0), bool)
    a = jax.jit(association.associate)(det, dmask, trk, tmask)
    assert not np.asarray(a.matched_det).any()
