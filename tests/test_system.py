"""End-to-end system behaviour: the paper's workload on the full stack,
plus multi-device integration (subprocess: device count is fixed at jax
init, so sharded tests get their own interpreter)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SortConfig, SortEngine, metrics
from repro.data import stream, synthetic


def test_sort_service_full_pipeline():
    """Paper Algorithm 1 over a packed multi-stream batch, with metrics."""
    seqs = []
    gts = []
    for i in range(4):
        cfg = synthetic.SceneConfig(num_frames=60, max_objects=6, seed=20 + i,
                                    miss_rate=0.03, fp_rate=0.05)
        gt_boxes, gt_mask, db, dm = synthetic.generate_scene(cfg)
        seqs.append((f"cam{i}", db, dm))
        gts.append((gt_boxes, gt_mask))
    batch = stream.pack(seqs, pad_multiple=4)
    eng = SortEngine(SortConfig(max_trackers=16,
                                max_detections=batch.det_boxes.shape[2]))
    state = eng.init(batch.det_boxes.shape[1])
    _, out = jax.jit(eng.run)(state, jnp.asarray(batch.det_boxes),
                              jnp.asarray(batch.det_mask))
    for i, (gt_boxes, gt_mask) in enumerate(gts):
        f = gt_boxes.shape[0]
        m = metrics.mota(gt_boxes, gt_mask,
                         np.asarray(out.boxes[:f, i]),
                         np.asarray(out.uid[:f, i]),
                         np.asarray(out.emit[:f, i]))
        assert m["mota"] > 0.4, (i, m)


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.models.config import ModelConfig
    from repro.models.model import build_model
    from repro.models.transformer import Parallel
    from repro.sharding.rules import params_pspecs
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import init_state, make_train_step

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(num_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                      d_ff=64, vocab_size=128, max_seq_len=32,
                      dtype="float32", moe=True, n_routed_experts=8,
                      n_shared_experts=1, moe_top_k=2, moe_d_ff=16,
                      first_k_dense=1, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    par_l = Parallel.local()
    par_m = Parallel(mesh=mesh, dp_axes=("data",), tp_axis="model")
    batch = {"tokens": jnp.ones((4, 16), jnp.int32),
             "labels": jnp.ones((4, 16), jnp.int32)}
    # sharded loss == local loss
    pspecs = params_pspecs(specs, params, mesh)
    shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                         is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.device_put(params, shard)
    l_local = float(model.loss(params, batch, par_l))
    l_shard = float(jax.jit(lambda p, b: model.loss(p, b, par_m))(params_sh,
                                                                  batch))
    assert abs(l_local - l_shard) < 5e-3, (l_local, l_shard)
    # one sharded train step runs and stays finite
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(model, par_m, opt))
    state = jax.device_put(init_state(params, opt),
                           type(init_state(params, opt))(
                               shard,
                               type(init_state(params, opt).opt_state)(
                                   shard, shard,
                                   NamedSharding(mesh, P())),
                               NamedSharding(mesh, P())))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    print(json.dumps({"ok": True, "l_local": l_local, "l_shard": l_shard}))
""")


@pytest.mark.slow
def test_multidevice_sharded_equals_local():
    r = subprocess.run([sys.executable, "-c", MULTIDEV], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
