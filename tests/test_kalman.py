"""Kalman filter: equivalence with the numpy reference + filter properties."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bbox, kalman
from repro.core.ref_numpy import KalmanBoxTracker


def _rand_box(rng):
    x1, y1 = rng.uniform(0, 500, 2)
    w, h = rng.uniform(10, 200, 2)
    return np.array([x1, y1, x1 + w, y1 + h])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_matches_reference_tracker(seed, steps):
    rng = np.random.default_rng(seed)
    box0 = _rand_box(rng)
    ref = KalmanBoxTracker(box0, uid=1)
    params = kalman.KalmanParams.default()
    x, p = kalman.init_state(jnp.asarray(bbox.xyxy_to_z(jnp.asarray(box0))))
    for _ in range(steps):
        ref.predict()
        x, p = kalman.predict(x, p, params)
        z_box = _rand_box(rng)
        ref.update(z_box)
        z = bbox.xyxy_to_z(jnp.asarray(z_box))
        x, p = kalman.update(x, p, z, params)
        # ours is f32, reference is f64: observed drift <= ~0.03px on
        # hundreds-of-px coordinates
        np.testing.assert_allclose(np.asarray(x), ref.x, rtol=2e-3, atol=0.1)
        np.testing.assert_allclose(np.asarray(p), ref.P, rtol=2e-3, atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_covariance_symmetric_psd(seed):
    rng = np.random.default_rng(seed)
    params = kalman.KalmanParams.default()
    x, p = kalman.init_state(jnp.asarray(bbox.xyxy_to_z(
        jnp.asarray(_rand_box(rng)))))
    for _ in range(5):
        x, p = kalman.predict(x, p, params)
        z = bbox.xyxy_to_z(jnp.asarray(_rand_box(rng)))
        x, p = kalman.update(x, p, z, params)
        pn = np.asarray(p)
        np.testing.assert_allclose(pn, pn.T, rtol=1e-3, atol=1e-3)
        eig = np.linalg.eigvalsh((pn + pn.T) / 2)
        assert eig.min() > -1e-3, eig


def test_update_reduces_uncertainty():
    params = kalman.KalmanParams.default()
    x, p = kalman.init_state(jnp.asarray([10.0, 10.0, 100.0, 1.0]))
    x, p_pred = kalman.predict(x, p, params)
    _, p_post = kalman.update(x, p_pred, jnp.asarray([11.0, 9.0, 102.0, 1.0]),
                              params)
    assert float(jnp.trace(p_post[:4, :4])) < float(jnp.trace(p_pred[:4, :4]))


def test_inv4_spd_exact():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 4, 4)).astype(np.float32)
    s = a @ a.transpose(0, 2, 1) + 0.5 * np.eye(4, dtype=np.float32)
    inv = np.asarray(kalman.inv4_spd(jnp.asarray(s)))
    np.testing.assert_allclose(inv @ s, np.broadcast_to(np.eye(4), s.shape),
                               atol=2e-3)


def test_scale_velocity_clamp():
    """SORT detail: predicted area may never go negative."""
    params = kalman.KalmanParams.default()
    x = jnp.asarray([10.0, 10.0, 5.0, 1.0, 0.0, 0.0, -10.0])  # ds << 0
    p = kalman.initial_covariance()
    x2, _ = kalman.predict(x, p, params)
    assert float(x2[2]) >= 0.0


def test_masked_update_is_selective():
    params = kalman.KalmanParams.default()
    x, p = kalman.init_state(jnp.asarray([[10.0, 10, 100, 1],
                                          [20.0, 20, 50, 2]]))
    z = jnp.asarray([[12.0, 11, 100, 1], [25.0, 25, 60, 2]])
    mask = jnp.asarray([True, False])
    x2, p2 = kalman.masked_update(x, p, z, mask, params)
    assert not np.allclose(np.asarray(x2[0]), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(x2[1]), np.asarray(x[1]))
    np.testing.assert_array_equal(np.asarray(p2[1]), np.asarray(p[1]))


def test_bbox_roundtrip():
    rng = np.random.default_rng(1)
    boxes = np.stack([_rand_box(rng) for _ in range(32)]).astype(np.float32)
    z = bbox.xyxy_to_z(jnp.asarray(boxes))
    back = np.asarray(bbox.z_to_xyxy(z))
    np.testing.assert_allclose(back, boxes, rtol=1e-4, atol=1e-2)
