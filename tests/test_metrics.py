"""Tracking metrics (core/metrics.py) against hand-computed small cases."""
import numpy as np

from repro.core import metrics


def _box(x, y, w=10.0, h=10.0):
    return [x, y, x + w, y + h]


# ---------------------------------------------------------- frame_matches
def test_frame_matches_perfect():
    gt = np.array([_box(0, 0), _box(100, 100)], np.float32)
    out = np.array([_box(100, 100), _box(0, 0)], np.float32)  # any order
    tp, fp, fn, pairs = metrics.frame_matches(
        gt, np.ones(2, bool), out, np.ones(2, bool))
    assert (tp, fp, fn) == (2, 0, 0)
    assert sorted(pairs) == [(0, 1), (1, 0)]


def test_frame_matches_counts_fp_and_fn():
    gt = np.array([_box(0, 0), _box(100, 100)], np.float32)
    out = np.array([_box(0, 0), _box(500, 500)], np.float32)  # 1 hit + 1 fp
    tp, fp, fn, pairs = metrics.frame_matches(
        gt, np.ones(2, bool), out, np.ones(2, bool))
    assert (tp, fp, fn) == (1, 1, 1)
    assert pairs == [(0, 0)]


def test_frame_matches_respects_iou_threshold():
    gt = np.array([_box(0, 0)], np.float32)
    out = np.array([_box(4, 0)], np.float32)   # IoU = 6/14 ≈ 0.43
    hit = metrics.frame_matches(gt, np.ones(1, bool), out, np.ones(1, bool),
                                iou_thr=0.4)
    miss = metrics.frame_matches(gt, np.ones(1, bool), out, np.ones(1, bool),
                                 iou_thr=0.5)
    assert (hit[0], miss[0]) == (1, 0)


def test_frame_matches_empty_edges():
    gt = np.array([_box(0, 0)], np.float32)
    out = np.array([_box(0, 0), _box(9, 9)], np.float32)
    none = np.zeros(1, bool)
    # no gt in frame: every reported box is a false positive
    assert metrics.frame_matches(gt, none, out, np.ones(2, bool))[:3] \
        == (0, 2, 0)
    # no output in frame: every gt is a miss
    assert metrics.frame_matches(gt, np.ones(1, bool), out,
                                 np.zeros(2, bool))[:3] == (0, 0, 1)
    # both empty
    assert metrics.frame_matches(gt, none, out, np.zeros(2, bool))[:3] \
        == (0, 0, 0)
    # masked-out rows must not match even if their boxes align
    tp, fp, fn, _ = metrics.frame_matches(
        gt, np.ones(1, bool), out, np.array([False, True]))
    assert (tp, fp, fn) == (0, 1, 1)


# ------------------------------------------------------------------- mota
def _stack(frames):
    """[(boxes [K, 4], mask [K])] per frame -> dense [F, K, ...] arrays."""
    return (np.stack([b for b, _ in frames]).astype(np.float32),
            np.stack([m for _, m in frames]).astype(bool))


def test_mota_perfect_tracking_is_one():
    f = 4
    gt_boxes = np.tile(np.array([_box(0, 0), _box(50, 50)], np.float32),
                       (f, 1, 1))
    gt_mask = np.ones((f, 2), bool)
    uids = np.tile(np.array([7, 9], np.int32), (f, 1))
    m = metrics.mota(gt_boxes, gt_mask, gt_boxes, uids, gt_mask)
    assert m == {"mota": 1.0, "tp": 8, "fp": 0, "fn": 0,
                 "id_switches": 0, "num_gt": 8}


def test_mota_counts_id_switch():
    """One object, 3 frames, tracker uid changes 1 -> 2 at frame 2:
    mota = 1 - (fn + fp + idsw)/num_gt = 1 - 1/3."""
    f = 3
    gt_boxes = np.tile(np.array([_box(0, 0)], np.float32), (f, 1, 1))
    gt_mask = np.ones((f, 1), bool)
    uids = np.array([[1], [1], [2]], np.int32)
    m = metrics.mota(gt_boxes, gt_mask, gt_boxes, uids, gt_mask)
    assert m["id_switches"] == 1 and m["tp"] == 3
    np.testing.assert_allclose(m["mota"], 1.0 - 1.0 / 3.0)


def test_mota_fn_fp_accounting():
    """2 objects x 2 frames; frame 1 misses object B (fn) and reports a
    far-away box instead (fp): mota = 1 - 2/4."""
    gt_boxes, gt_mask = _stack([
        (np.array([_box(0, 0), _box(50, 50)]), np.array([True, True])),
        (np.array([_box(0, 0), _box(50, 50)]), np.array([True, True])),
    ])
    out_boxes, out_emit = _stack([
        (np.array([_box(0, 0), _box(50, 50)]), np.array([True, True])),
        (np.array([_box(0, 0), _box(500, 500)]), np.array([True, True])),
    ])
    uids = np.full((2, 2), 0, np.int32)
    uids[:, 1] = 1
    m = metrics.mota(gt_boxes, gt_mask, out_boxes, uids, out_emit)
    assert m["tp"] == 3 and m["fp"] == 1 and m["fn"] == 1
    assert m["id_switches"] == 0
    np.testing.assert_allclose(m["mota"], 0.5)


def test_mota_empty_frames_and_empty_gt():
    """Frames where neither gt nor tracker reports anything contribute
    nothing; an all-empty gt keeps mota finite (num_gt clamp)."""
    gt_boxes = np.zeros((3, 1, 4), np.float32)
    gt_mask = np.zeros((3, 1), bool)
    out_boxes = np.zeros((3, 1, 4), np.float32)
    out_emit = np.zeros((3, 1), bool)
    uids = np.zeros((3, 1), np.int32)
    m = metrics.mota(gt_boxes, gt_mask, out_boxes, uids, out_emit)
    assert m == {"mota": 1.0, "tp": 0, "fp": 0, "fn": 0,
                 "id_switches": 0, "num_gt": 0}
    # empty gt + spurious output -> pure fp, mota clamps on num_gt >= 1
    out_emit[1, 0] = True
    m = metrics.mota(gt_boxes, gt_mask, out_boxes, uids, out_emit)
    assert m["fp"] == 1 and m["mota"] == 0.0
