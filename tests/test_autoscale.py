"""Elastic lane budgets: equivalence + recompilation lockdown (DESIGN.md §8).

The load-bearing invariants:

* **equivalence** — an elastic scheduler's per-sequence outputs are
  bit-identical to a fixed ``max_lanes`` scheduler's, on both engine
  paths and both association modes, under arbitrary admission/drain churn
  and forced resizes, including over a ``("lanes",)`` device mesh.
  Migration moves every kept lane (mid-sequence lanes included) bit for
  bit; appended lanes are a masked re-init.
* **recompilation lock** — the chunk scan compiles at most once per
  ladder width; repeated grow/shrink cycles never retrace (the
  scheduler's ``trace_log`` records one entry per chunk-shape trace).
* **shrink-by-drain** — a requested shrink never drops the budget while
  an evacuating lane holds a live sequence; uids never alias; the reorder
  buffer stays in submission order (cross-checked against the numpy
  oracle, the PR 4 lifecycle-audit pattern).

The mesh cases need simulated devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the CI
``multi-device`` job) and skip elsewhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import SortConfig, SortEngine, resize_streams, slots
from repro.core.ref_numpy import Sort as RefSort
from repro.core.sort import sort_state_of
from repro.data.synthetic import SceneConfig, generate_scene
from repro.serve import StreamScheduler, lane_ladder
from repro.sharding import lane_mesh

NDEV = jax.device_count()
needs_multi = pytest.mark.skipif(
    NDEV < 4, reason="needs >=4 devices: run with XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8")

MAX_DETS = 7
PATHS = [(False, "hungarian"), (False, "greedy"),
         (True, "hungarian"), (True, "greedy")]
_ENGINES: dict = {}


def _scene(seed, frames):
    _, _, db, dm = generate_scene(
        SceneConfig(num_frames=frames, max_objects=4, seed=seed))
    d = db.shape[1]
    assert d <= MAX_DETS, d
    return (np.pad(db, ((0, 0), (0, MAX_DETS - d), (0, 0))),
            np.pad(dm, ((0, 0), (0, MAX_DETS - d))))


def _engine(use_kernels, assoc="hungarian"):
    key = (use_kernels, assoc)
    if key not in _ENGINES:
        _ENGINES[key] = SortEngine(SortConfig(
            max_trackers=8, max_detections=MAX_DETS,
            use_kernels=use_kernels, assoc=assoc))
    return _ENGINES[key]


def _assert_results_equal(a, b):
    assert [r.name for r in a] == [r.name for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.uid, rb.uid, err_msg=ra.name)
        np.testing.assert_array_equal(ra.emit, rb.emit, err_msg=ra.name)
        np.testing.assert_array_equal(ra.boxes, rb.boxes, err_msg=ra.name)


def _churn(el, ref, seqs, widths):
    """Interleave submits, chunk dispatches, and forced resizes on the
    elastic scheduler; feed the fixed reference the same sequences.
    Returns (elastic results, reference results), both submission-order
    complete."""
    got = []
    for i, (name, db, dm) in enumerate(seqs):
        el.submit(name, db, dm)
        ref.submit(name, db, dm)
        if widths and i % 2 == 1:
            el.request_width(widths[(i // 2) % len(widths)])
            got.extend(el._run_chunk())
    el.request_width(None)          # release the pin; drain on policy
    got.extend(el.run())
    return got, ref.run()


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("use_kernels,assoc", PATHS)
def test_elastic_bit_identical_to_fixed_max(use_kernels, assoc):
    """2x2 grid (engine path x assoc mode): ragged churn with forced
    grow/shrink through every ladder width equals a fixed max_lanes run
    bit for bit — migration never perturbs a lane mid-sequence."""
    lengths = [12, 5, 9, 1, 7, 12, 3, 5]
    seqs = [(f"e{i}", *_scene(i, f)) for i, f in enumerate(lengths)]
    eng = _engine(use_kernels, assoc)
    el = StreamScheduler(eng, chunk=4, min_lanes=1, max_lanes=4)
    ref = StreamScheduler(eng, num_lanes=4, chunk=4)
    out_el, out_ref = _churn(el, ref, seqs, widths=[4, 1, 2])
    _assert_results_equal(out_el, out_ref)
    assert len(el.resizes) > 0          # the churn really resized


def test_elastic_policy_grows_and_shrinks_without_forcing():
    """Demand-driven policy alone: a burst grows the budget, the drain
    tail shrinks it back after `shrink_patience` boundaries, and outputs
    still equal the fixed max_lanes run."""
    eng = _engine(True)
    el = StreamScheduler(eng, chunk=4, min_lanes=2, max_lanes=8,
                         shrink_patience=2)
    ref = StreamScheduler(eng, num_lanes=8, chunk=4)
    seqs = [("long", *_scene(0, 40))] + \
        [(f"s{i}", *_scene(1 + i, 4)) for i in range(7)]
    for name, db, dm in seqs:
        el.submit(name, db, dm)
        ref.submit(name, db, dm)
    _assert_results_equal(el.run(), ref.run())
    grew = [r for r in el.resizes if r[2] > r[1]]
    shrank = [r for r in el.resizes if r[2] < r[1]]
    assert grew and shrank, el.resizes
    assert el.num_lanes < 8             # drained back down


# ------------------------------------------------------ recompilation lock
def test_ladder_precompiles_once_per_width():
    """Construction pre-compiles every ladder width exactly once (on
    throwaway all-inactive chunks), and repeated grow/shrink cycles add
    ZERO new traces — resizing is recompilation-free."""
    eng = _engine(True)
    el = StreamScheduler(eng, chunk=4, min_lanes=1, max_lanes=4)
    assert sorted(el.trace_log) == [1, 2, 4]    # one trace per width
    n0 = len(el.trace_log)
    for cycle in range(3):
        for w in (4, 1, 2, 4, 2):
            el.request_width(w)
            for i in range(2):
                el.submit(f"c{cycle}w{w}s{i}", *_scene(i, 5))
            el.run()
    el.request_width(None)
    assert len(el.trace_log) == n0, (
        f"resizing retraced the chunk program: {el.trace_log}")


def test_lazy_compile_is_still_once_per_width():
    """precompile=False compiles lazily but still at most once per
    ladder width across arbitrarily many resizes."""
    eng = _engine(True)
    el = StreamScheduler(eng, chunk=4, min_lanes=1, max_lanes=4,
                         precompile=False)
    assert el.trace_log == []
    for w in (1, 4, 2, 1, 4, 2, 4, 1):
        el.request_width(w)
        el.submit(f"w{w}", *_scene(0, 5))
        el.run()
    assert len(el.trace_log) <= len(el.ladder)
    assert len(set(el.trace_log)) == len(el.trace_log)  # no width twice


# -------------------------------------------------- accounting regressions
def test_utilization_zero_before_any_dispatch():
    """utilization on a never-dispatched scheduler is 0.0, not a division
    error — fixed and elastic alike."""
    eng = _engine(False)
    assert StreamScheduler(eng, num_lanes=2).utilization == 0.0
    el = StreamScheduler(eng, min_lanes=1, max_lanes=2, precompile=False)
    assert el.utilization == 0.0
    assert el.lane_steps == 0 and el.frames_processed == 0


def test_lane_steps_use_the_width_active_at_each_chunk():
    """The utilization denominator must charge each chunk at the width it
    actually dispatched, not the construction width."""
    eng = _engine(False)
    el = StreamScheduler(eng, chunk=2, min_lanes=2, max_lanes=4,
                         precompile=False)
    for i in range(2):                       # phase A: width 2, saturated
        el.submit(f"a{i}", *_scene(i, 4))
    el.run()
    assert el.num_lanes == 2 and el.lane_steps == 8   # 4 steps x 2 lanes
    for i in range(4):                       # phase B: grows to 4
        el.submit(f"b{i}", *_scene(i, 4))
    el.run()
    assert el.num_lanes == 4
    # + 4 steps x 4 lanes; at the construction width it would be +8
    assert el.lane_steps == 8 + 16
    assert el.frames_processed == 2 * 4 + 4 * 4
    assert el.utilization == 1.0


def test_fifo_fairness_across_a_forced_shrink():
    """A pinned shrink re-queues admissions into the surviving lanes:
    admission order stays exactly submission order, admission steps stay
    monotone, and every sequence completes."""
    eng = _engine(True)
    el = StreamScheduler(eng, num_lanes=4, chunk=2, min_lanes=2,
                         max_lanes=4)
    ref = StreamScheduler(eng, num_lanes=4, chunk=2)
    seqs = [(f"f{i}", *_scene(i, 8 if i < 4 else 4)) for i in range(8)]
    got = []
    for name, db, dm in seqs[:4]:
        el.submit(name, db, dm)
        ref.submit(name, db, dm)
    got.extend(el._run_chunk())              # all four lanes occupied
    el.request_width(2)                      # evacuate lanes 2-3
    for name, db, dm in seqs[4:]:            # these must re-queue
        el.submit(name, db, dm)
        ref.submit(name, db, dm)
    while el.busy:
        got.extend(el._run_chunk())
    _assert_results_equal(got, ref.run())
    assert el.num_lanes == 2                 # shrink landed once drained
    admitted = [i for i, _ in el.admissions]
    steps = [s for _, s in el.admissions]
    assert admitted == list(range(8))        # FIFO, nothing skipped
    assert steps == sorted(steps)


# --------------------------------------------------- shrink-by-drain trace
def test_shrink_waits_for_evacuating_lanes_to_drain():
    """Hand-stepped shrink-drain protocol (the PR 4 lifecycle-audit
    pattern): a shrink pinned while lanes 2-3 still hold live sequences
    must hold the budget at 4 until both drain, then land exactly once;
    per-sequence outputs match the numpy oracle (so no uid ever aliases
    and no frame is lost), and the reorder buffer releases in submission
    order even though the evacuating lanes finish first."""
    eng = _engine(True, "hungarian")
    el = StreamScheduler(eng, num_lanes=4, chunk=2, min_lanes=2,
                         max_lanes=4)
    lengths = {"a": 12, "b": 12, "evac_c": 5, "evac_d": 7}
    seqs = [(n, *_scene(40 + i, f))
            for i, (n, f) in enumerate(lengths.items())]
    got = []
    for name, db, dm in seqs:
        el.submit(name, db, dm)
    got.extend(el._run_chunk())              # chunk 0: lanes 0..3 occupied
    el.request_width(2)
    widths = []
    while el.busy:
        got.extend(el._run_chunk())
        widths.append(el.num_lanes)
    # evac_c ends at step 5 (chunk 2), evac_d at step 7 (chunk 3): the
    # budget must hold at 4 through chunk 3 and drop at the chunk-4
    # boundary — exactly one resize, never mid-occupancy.
    assert widths[:3] == [4, 4, 4] and set(widths[3:]) == {2}, widths
    assert el.resizes == [(4, 4, 2)]
    # in-order release despite the evacuating lanes finishing first
    assert [t.name for t in got] == [n for n, _, _ in seqs]
    # numpy-oracle cross-check: identities and boxes per frame
    for (name, db, dm), tracks in zip(seqs, got):
        ref = RefSort(assoc="hungarian")
        for t in range(db.shape[0]):
            ref_rows = ref.update(db[t][dm[t]])
            em = tracks.emit[t]
            ids_ours = sorted(int(u) for u in tracks.uid[t][em])
            ids_ref = sorted(int(r[4]) for r in ref_rows)
            assert ids_ours == ids_ref, f"{name} frame {t}"
            boxes = {int(u): tracks.boxes[t, k]
                     for k, u in enumerate(tracks.uid[t]) if em[k]}
            for r in ref_rows:
                np.testing.assert_allclose(
                    boxes[int(r[4])], r[:4], rtol=1e-3, atol=0.5,
                    err_msg=f"{name} frame {t} uid {r[4]}")


# ------------------------------------------------------- migration (unit)
@pytest.mark.parametrize("use_kernels", [False, True])
def test_resize_round_trip_is_bit_exact(use_kernels):
    """grow -> shrink returns the original state bit for bit on both
    layouts, and grown lanes equal a fresh init (the masked re-init)."""
    eng = _engine(use_kernels)
    state = eng.init_ragged(3)
    db, dm = _scene(7, 6)
    frames = jnp.asarray(np.stack([db] * 3, axis=1))
    masks = jnp.asarray(np.stack([dm] * 3, axis=1))
    active = jnp.ones((3,), bool)
    for f in range(6):
        state, _ = eng.step_ragged(state, frames[f], masks[f], active)
    big = eng.resize_ragged(state, 3, 8)
    back = eng.resize_ragged(big, 8, 3)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    big_e = sort_state_of(big, 8) if use_kernels else big
    fresh = eng.init(8)
    for a, b in zip(jax.tree.leaves(big_e), jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(a)[3:], np.asarray(b)[3:])


def test_resize_pool_and_streams_validation():
    pool = slots.init_pool((4,), 3)
    assert slots.resize_pool(pool, 4) is pool
    small = slots.resize_pool(pool, 2)
    assert small.alive.shape == (2, 3) and small.next_uid.shape == (2,)
    big = slots.resize_pool(pool, 6)
    assert bool((~np.asarray(big.alive[4:])).all())
    assert (np.asarray(big.uid[4:]) == -1).all()
    assert (np.asarray(big.next_uid[4:]) == 1).all()
    with pytest.raises(ValueError):
        slots.resize_pool(pool, 0)
    with pytest.raises(ValueError):
        resize_streams(_engine(False).init(2), 0)


def test_ladder_and_constructor_validation():
    assert lane_ladder(2, 16) == (2, 4, 8, 16)
    assert lane_ladder(3, 12) == (3, 6, 12)
    assert lane_ladder(4, 4) == (4,)
    with pytest.raises(ValueError, match="2\\*\\*k"):
        lane_ladder(2, 12)
    with pytest.raises(ValueError, match="min_lanes"):
        lane_ladder(0, 4)
    with pytest.raises(ValueError, match=">="):
        lane_ladder(8, 4)
    eng = _engine(False)
    with pytest.raises(ValueError, match="both"):
        StreamScheduler(eng, min_lanes=2)
    with pytest.raises(ValueError, match="ladder width"):
        StreamScheduler(eng, num_lanes=3, min_lanes=2, max_lanes=8)
    with pytest.raises(ValueError, match="num_lanes"):
        StreamScheduler(eng)
    fixed = StreamScheduler(eng, num_lanes=2)
    with pytest.raises(ValueError, match="elastic"):
        fixed.request_width(2)
    el = StreamScheduler(eng, min_lanes=2, max_lanes=4, precompile=False)
    with pytest.raises(ValueError, match="ladder"):
        el.request_width(3)


# ------------------------------------------------------------- mesh mode
def test_elastic_mesh_of_one_matches_fixed_unsharded():
    """The sharded elastic path with a single-device mesh equals the
    fixed max_lanes unsharded run — keeps the shard_map + migrate path
    exercised in every session."""
    eng = _engine(True)
    seqs = [(f"m{i}", *_scene(60 + i, f)) for i, f in enumerate([6, 3, 8, 2])]
    el = StreamScheduler(eng, chunk=4, mesh=lane_mesh(1),
                         min_lanes=1, max_lanes=4)
    ref = StreamScheduler(eng, num_lanes=4, chunk=4)
    out_el, out_ref = _churn(el, ref, seqs, widths=[4, 1])
    _assert_results_equal(out_el, out_ref)
    assert len(el.resizes) > 0


@needs_multi
@pytest.mark.parametrize("use_kernels", [False, True])
def test_elastic_sharded_bit_identical_to_fixed_max(use_kernels):
    """Elastic over a 4-device ("lanes",) mesh: churn + forced resizes
    equal the fixed max_lanes unsharded run bit for bit — migration
    crosses shard boundaries (lanes redistribute over devices at every
    width change) without perturbing a single lane."""
    eng = _engine(use_kernels)
    seqs = [(f"s{i}", *_scene(80 + i, f))
            for i, f in enumerate([12, 5, 9, 5, 1, 7, 3, 10])]
    el = StreamScheduler(eng, chunk=4, mesh=lane_mesh(4),
                         min_lanes=4, max_lanes=16)
    ref = StreamScheduler(eng, num_lanes=16, chunk=4)
    out_el, out_ref = _churn(el, ref, seqs, widths=[16, 4, 8])
    _assert_results_equal(out_el, out_ref)
    assert len(el.resizes) > 0


@needs_multi
def test_migrated_state_stays_lane_sharded():
    """After a resize the resident state is already placed with the new
    width's NamedSharding — no leaf collapses to a replicated or
    single-device layout, so no chunk pays a resharding copy."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.sharding import state_pspecs

    eng = _engine(True)
    el = StreamScheduler(eng, chunk=4, mesh=lane_mesh(4),
                         min_lanes=4, max_lanes=8)
    for i, f in enumerate([9, 4, 7, 6, 5, 8]):
        el.submit(f"r{i}", *_scene(90 + i, f))
    el.run()
    assert len(el.resizes) > 0
    specs = state_pspecs(el._state)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    for leaf, spec in zip(jax.tree.leaves(el._state), spec_leaves):
        assert isinstance(leaf.sharding, NamedSharding), leaf.shape
        assert leaf.sharding.spec == spec, (leaf.shape, leaf.sharding.spec)


@needs_multi
def test_every_ladder_width_must_divide_the_mesh():
    with pytest.raises(ValueError, match="divide"):
        StreamScheduler(_engine(True), mesh=lane_mesh(4),
                        min_lanes=2, max_lanes=8)


# ------------------------------------------------------- property coverage
@pytest.mark.slow
@pytest.mark.parametrize("use_kernels,assoc", PATHS)
@settings(max_examples=5, deadline=None, derandomize=True)
@given(lengths=st.lists(st.sampled_from([1, 4, 9, 12]), min_size=1,
                        max_size=8),
       widths=st.lists(st.sampled_from([1, 2, 4]), min_size=1, max_size=4))
def test_elastic_equivalence_property(use_kernels, assoc, lengths, widths):
    """Any ragged admission/drain churn with any forced-resize pattern
    stays bit-identical to the fixed max_lanes scheduler, on every
    engine path x assoc mode (schedulers are reused across examples so
    the ladder compiles once per combination)."""
    key = ("prop", use_kernels, assoc)
    if key not in _ENGINES:
        eng = _engine(use_kernels, assoc)
        _ENGINES[key] = (
            StreamScheduler(eng, chunk=4, min_lanes=1, max_lanes=4),
            StreamScheduler(eng, num_lanes=4, chunk=4))
    el, ref = _ENGINES[key]
    seqs = [(f"p{i}", *_scene(20 + i, f)) for i, f in enumerate(lengths)]
    out_el, out_ref = _churn(el, ref, seqs, widths)
    _assert_results_equal(out_el, out_ref)
