"""Checkpointing: roundtrip, atomic commit, retention, async writer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 8)),
                      "b": jnp.zeros((8,))},
            "step": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), t)
    restored, step = ck.restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_multiple_steps(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1, t))
    assert ck.latest_step(str(tmp_path)) == 2
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 2
    restored, step = ck.restore(str(tmp_path), t, step=1)
    assert step == 1


def test_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 1, _tree())
    bad = {"layer": {"w": np.zeros((5, 8)), "b": np.zeros(8)},
           "step": np.zeros((), np.int32)}
    with pytest.raises(AssertionError):
        ck.restore(str(tmp_path), bad)


def test_no_partial_commit(tmp_path):
    """A crash before LATEST is written must leave no visible checkpoint."""
    assert ck.latest_step(str(tmp_path)) is None
    # simulate: directory exists but LATEST never committed
    os.makedirs(tmp_path / "step_000000009")
    assert ck.latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path), _tree())


def test_manager_async_and_gc(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("4")
    assert ck.latest_step(str(tmp_path)) == 4


def test_restore_respects_dtype_and_structure(tmp_path):
    t = {"a": jnp.asarray([1, 2], jnp.int32),
         "nested": [jnp.ones((2, 2), jnp.bfloat16)]}
    ck.save(str(tmp_path), 1, t)
    restored, _ = ck.restore(str(tmp_path), t)
    assert restored["a"].dtype == np.int32
    assert np.asarray(restored["nested"][0]).dtype == jnp.bfloat16
