"""Checkpointing: roundtrip, atomic commit, retention, async writer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 8)),
                      "b": jnp.zeros((8,))},
            "step": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), t)
    restored, step = ck.restore(str(tmp_path), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_multiple_steps(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1, t))
    assert ck.latest_step(str(tmp_path)) == 2
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 2
    restored, step = ck.restore(str(tmp_path), t, step=1)
    assert step == 1


def test_shape_mismatch_rejected(tmp_path):
    ck.save(str(tmp_path), 1, _tree())
    bad = {"layer": {"w": np.zeros((5, 8)), "b": np.zeros(8)},
           "step": np.zeros((), np.int32)}
    with pytest.raises(AssertionError):
        ck.restore(str(tmp_path), bad)


def test_no_partial_commit(tmp_path):
    """A crash before LATEST is written must leave no visible checkpoint."""
    assert ck.latest_step(str(tmp_path)) is None
    # simulate: directory exists but LATEST never committed
    os.makedirs(tmp_path / "step_000000009")
    assert ck.latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path), _tree())


def test_manager_async_and_gc(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1].endswith("4")
    assert ck.latest_step(str(tmp_path)) == 4


def test_restore_respects_dtype_and_structure(tmp_path):
    t = {"a": jnp.asarray([1, 2], jnp.int32),
         "nested": [jnp.ones((2, 2), jnp.bfloat16)]}
    ck.save(str(tmp_path), 1, t)
    restored, _ = ck.restore(str(tmp_path), t)
    assert restored["a"].dtype == np.int32
    assert np.asarray(restored["nested"][0]).dtype == jnp.bfloat16


# ------------------------------------------------- crash-recovery contract
def test_async_write_failure_raises_on_wait(tmp_path, monkeypatch):
    """A failed background write must surface — on wait() — never be
    mistaken for a committed checkpoint (the silent-loss regression)."""
    mgr = ck.CheckpointManager(str(tmp_path), keep=2)

    def boom(*a, **k):
        raise OSError("disk full")
    monkeypatch.setattr(ck, "save", boom)
    mgr.save_async(1, _tree())
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the error is consumed once surfaced; the manager is reusable
    monkeypatch.undo()
    mgr.save_async(2, _tree())
    mgr.wait()
    assert ck.latest_step(str(tmp_path)) == 2


def test_async_write_failure_raises_on_next_save(tmp_path, monkeypatch):
    mgr = ck.CheckpointManager(str(tmp_path))

    def boom(*a, **k):
        raise OSError("quota exceeded")
    monkeypatch.setattr(ck, "save", boom)
    mgr.save_async(1, _tree())
    mgr._thread.join()           # let the failure land without consuming it
    with pytest.raises(OSError, match="quota exceeded"):
        mgr.save_async(2, _tree())


def test_stale_tmp_dirs_swept_fresh_kept(tmp_path):
    """Debris of a writer killed between mkdtemp and os.replace is GC'd
    once stale; a live (fresh) writer's temp dir survives the sweep."""
    stale = tmp_path / ".tmp_ckpt_dead"
    fresh = tmp_path / ".tmp_ckpt_live"
    stale.mkdir()
    fresh.mkdir()
    os.utime(stale, (0, 0))      # ancient mtime
    mgr = ck.CheckpointManager(str(tmp_path), keep=1)   # sweeps at init
    assert not stale.exists()
    assert fresh.exists()
    mgr.save_async(1, _tree())
    mgr.wait()                   # sweeps again via _gc
    assert fresh.exists()        # still younger than stale_tmp_age


def test_gc_skips_foreign_step_names(tmp_path):
    (tmp_path / "step_final").mkdir()          # unparseable step number
    mgr = ck.CheckpointManager(str(tmp_path), keep=1)
    for s in (1, 2, 3):
        mgr.save_async(s, _tree())
    mgr.wait()                   # _gc must not crash on / delete step_final
    assert (tmp_path / "step_final").exists()
    steps = [d for d in os.listdir(tmp_path)
             if d.startswith("step_") and d != "step_final"]
    assert steps == ["step_000000003"]


def test_keep_zero_rejected(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        ck.CheckpointManager(str(tmp_path), keep=0)


def test_restore_missing_keys_is_diagnosable(tmp_path):
    """Restoring onto a mismatched tree names the offending paths in a
    ValueError instead of dying with a bare npz KeyError."""
    ck.save(str(tmp_path), 1, _tree())
    bad = {"layer": {"w": np.zeros((4, 8), np.float32),
                     "extra": np.zeros(3)},
           "step": np.zeros((), np.int32)}
    with pytest.raises(ValueError) as ei:
        ck.restore(str(tmp_path), bad)
    msg = str(ei.value)
    assert "layer/extra" in msg          # the missing requested path
    assert "layer/b" in msg              # the checkpoint-only path


def test_stale_latest_falls_back_to_committed(tmp_path):
    """A kill between the step-dir rename and the LATEST commit (or after
    its target was GC'd) must land the restore on the newest COMMITTED
    step, not fail on the stale pointer."""
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1, t))
    # crash simulation: LATEST points at a step whose dir never completed
    with open(tmp_path / "LATEST", "w") as fh:
        fh.write("9")
    os.makedirs(tmp_path / "step_000000009")   # present but no manifest
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["step"]), 4)
    flat, fstep = ck.restore_flat(str(tmp_path))
    assert fstep == 2
    # an explicit step is trusted verbatim
    _, s1 = ck.restore(str(tmp_path), t, step=1)
    assert s1 == 1


def test_restore_flat_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 5, t)
    flat, step = ck.restore_flat(str(tmp_path))
    assert step == 5
    keys, leaves, _ = ck.flatten_with_paths(t)
    assert sorted(flat) == sorted(keys)
    for k, leaf in zip(keys, leaves):
        np.testing.assert_array_equal(flat[k], np.asarray(leaf))


def test_committed_steps_ignores_incomplete(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    ck.save(str(tmp_path), 7, t)
    os.makedirs(tmp_path / "step_000000011")   # no manifest: uncommitted
    (tmp_path / "step_junk").mkdir()
    assert ck.committed_steps(str(tmp_path)) == [3, 7]
