"""Data pipeline: synthetic scenes, MOT15 IO, stream packing, token streams."""
import io

import numpy as np

from repro.data import mot, stream, synthetic, tokens


def test_synthetic_scene_shapes():
    cfg = synthetic.SceneConfig(num_frames=50, max_objects=6, seed=0)
    gt_boxes, gt_mask, det_boxes, det_mask = synthetic.generate_scene(cfg)
    assert gt_boxes.shape[0] == 50 and det_boxes.shape[0] == 50
    assert det_boxes.shape[2] == 4
    # detections are valid boxes
    v = det_boxes[det_mask]
    assert (v[:, 2] >= v[:, 0]).all() and (v[:, 3] >= v[:, 1]).all()
    # most ground-truth objects are detected most frames
    assert det_mask.sum() > 0.5 * gt_mask.sum()


def test_mot15_roundtrip(tmp_path):
    cfg = synthetic.SceneConfig(num_frames=20, max_objects=4, seed=1)
    _, _, det_boxes, det_mask = synthetic.generate_scene(cfg)
    p = tmp_path / "det.txt"
    mot.write_det_file(p, det_boxes, det_mask)
    rb, rm = mot.read_det_file(p)
    assert rm.sum() == det_mask.sum()
    # boxes survive the roundtrip (order within frame preserved)
    np.testing.assert_allclose(rb[rm], det_boxes[det_mask], atol=0.05)


def test_mot15_conf_filter():
    txt = "1,-1,10,10,20,20,0.9,-1,-1,-1\n1,-1,50,50,20,20,0.1,-1,-1,-1\n"
    rb, rm = mot.read_det_file(io.StringIO(txt), min_conf=0.5)
    assert rm.sum() == 1


def test_stream_packing_and_buckets():
    seqs = []
    for i, f in enumerate([30, 10, 20, 40]):
        cfg = synthetic.SceneConfig(num_frames=f, max_objects=4, seed=i)
        _, _, db, dm = synthetic.generate_scene(cfg)
        seqs.append((f"s{i}", db, dm))
    batch = stream.pack(seqs, pad_multiple=8)
    assert batch.det_boxes.shape[0] == 40          # longest
    assert batch.det_boxes.shape[1] == 8           # padded stream axis
    assert batch.frame_valid[:10, 1].all() and not batch.frame_valid[10:, 1].any()
    buckets = stream.length_buckets(seqs, num_buckets=2)
    assert len(buckets) == 2
    lens0 = [s[1].shape[0] for s in buckets[0]]
    lens1 = [s[1].shape[0] for s in buckets[1]]
    assert max(lens0) <= min(lens1)
    rep = stream.replicate(seqs, 7)
    assert len(rep) == 28  # paper §VI: 11 files x 7


def test_table_i_constants():
    assert len(mot.TABLE_I) == 11
    assert sum(f for f, _ in mot.TABLE_I.values()) == 5500  # paper Table VI


def test_token_stream_learnable():
    ts = tokens.TokenStream(vocab_size=100, seed=0)
    b = ts.batch(4, 64)
    assert b["tokens"].shape == (4, 64) and b["labels"].shape == (4, 64)
    # bigram structure: most transitions follow the table
    follow = (ts._next[b["tokens"]] == b["labels"]).mean()
    assert follow > 0.8


def test_audio_and_vision_batches():
    rng = np.random.default_rng(0)
    ab = tokens.audio_batch(rng, 2, 128, 16, 50, mask_rate=0.3)
    assert ab["feats"].shape == (2, 128, 16)
    assert ab["mask_spans"].any() and not ab["mask_spans"].all()
    ts = tokens.TokenStream(100)
    vb = tokens.vision_batch(rng, 2, 24, 4, 8, 100, ts)
    assert vb["patches"].shape == (2, 4, 8)
    assert vb["tokens"].shape == (2, 24)
