"""Data pipeline: synthetic scenes, MOT15 IO, stream packing, token streams."""
import io

import numpy as np

from repro.data import mot, stream, synthetic, tokens


def test_synthetic_scene_shapes():
    cfg = synthetic.SceneConfig(num_frames=50, max_objects=6, seed=0)
    gt_boxes, gt_mask, det_boxes, det_mask = synthetic.generate_scene(cfg)
    assert gt_boxes.shape[0] == 50 and det_boxes.shape[0] == 50
    assert det_boxes.shape[2] == 4
    # detections are valid boxes
    v = det_boxes[det_mask]
    assert (v[:, 2] >= v[:, 0]).all() and (v[:, 3] >= v[:, 1]).all()
    # most ground-truth objects are detected most frames
    assert det_mask.sum() > 0.5 * gt_mask.sum()


def test_mot15_roundtrip(tmp_path):
    cfg = synthetic.SceneConfig(num_frames=20, max_objects=4, seed=1)
    _, _, det_boxes, det_mask = synthetic.generate_scene(cfg)
    p = tmp_path / "det.txt"
    mot.write_det_file(p, det_boxes, det_mask)
    rb, rm = mot.read_det_file(p)
    assert rm.sum() == det_mask.sum()
    # boxes survive the roundtrip (order within frame preserved)
    np.testing.assert_allclose(rb[rm], det_boxes[det_mask], atol=0.05)


def test_mot15_conf_filter():
    txt = "1,-1,10,10,20,20,0.9,-1,-1,-1\n1,-1,50,50,20,20,0.1,-1,-1,-1\n"
    rb, rm = mot.read_det_file(io.StringIO(txt), min_conf=0.5)
    assert rm.sum() == 1


def test_mot15_det_file_layout(tmp_path):
    """write_det_file emits the MOTChallenge det.txt column layout:
    frame(1-indexed), id=-1, bb_left, bb_top, bb_width, bb_height,
    conf=1, x=y=z=-1."""
    det_boxes = np.zeros((2, 2, 4), np.float32)
    det_boxes[0, 0] = [10.0, 20.0, 40.0, 80.0]      # xyxy -> w=30, h=60
    det_boxes[1, 1] = [5.0, 5.0, 15.0, 25.0]
    det_mask = np.array([[True, False], [False, True]])
    p = tmp_path / "det.txt"
    mot.write_det_file(p, det_boxes, det_mask)
    rows = [line.split(",") for line in p.read_text().splitlines()]
    assert [len(r) for r in rows] == [10, 10]       # masked rows not written
    frame, tid, x, y, w, h, conf, xx, yy, zz = rows[0]
    assert (frame, tid, conf, xx, yy, zz) == ("1", "-1", "1", "-1", "-1", "-1")
    np.testing.assert_allclose([float(v) for v in (x, y, w, h)],
                               [10.0, 20.0, 30.0, 60.0])
    assert rows[1][0] == "2"                        # frames are 1-indexed


def test_mot15_results_layout(tmp_path):
    """write_results emits the MOT15 submission layout (same 10 columns,
    uid in the id slot) for emitted slots only."""
    boxes = np.zeros((2, 3, 4), np.float32)
    boxes[0, 1] = [100.0, 50.0, 160.0, 170.0]       # w=60, h=120
    boxes[1, 0] = [0.0, 0.0, 10.0, 10.0]
    boxes[1, 2] = [1.0, 2.0, 4.0, 8.0]
    uids = np.array([[-1, 7, -1], [3, -1, 9]], np.int32)
    emit = np.array([[False, True, False], [True, False, True]])
    p = tmp_path / "res.txt"
    mot.write_results(p, boxes, uids, emit)
    rows = [line.split(",") for line in p.read_text().splitlines()]
    assert len(rows) == 3 and all(len(r) == 10 for r in rows)
    assert [r[0] for r in rows] == ["1", "2", "2"]  # 1-indexed frame order
    assert [r[1] for r in rows] == ["7", "3", "9"]  # uid column
    np.testing.assert_allclose([float(v) for v in rows[0][2:6]],
                               [100.0, 50.0, 60.0, 120.0])
    assert all(r[6:] == ["1", "-1", "-1", "-1"] for r in rows)


def test_mot15_write_read_roundtrip_is_exact_on_clean_values(tmp_path):
    """write_det_file -> read_det_file preserves boxes exactly when the
    coordinates survive the 2-decimal text format."""
    rng = np.random.default_rng(3)
    det_boxes = np.round(rng.uniform(0, 500, (6, 3, 4)).astype(np.float32),
                         2)
    det_boxes[..., 2:] = det_boxes[..., :2] + np.round(
        rng.uniform(1, 50, (6, 3, 2)).astype(np.float32), 2)
    det_mask = rng.random((6, 3)) < 0.7
    det_mask[4] = False                              # empty frame mid-file
    p = tmp_path / "det.txt"
    mot.write_det_file(p, det_boxes, det_mask)
    rb, rm = mot.read_det_file(p)
    # trailing all-empty frames are unrepresentable in the line format,
    # leading/mid ones round-trip
    f = 6 if det_mask[5].any() else int(np.nonzero(det_mask.any(1))[0][-1]) + 1
    assert rb.shape[0] == f
    # reader packs each frame's detections contiguously; counts and
    # within-frame order survive
    np.testing.assert_array_equal(rm.sum(1), det_mask[:f].sum(1))
    np.testing.assert_allclose(rb[rm], det_boxes[:f][det_mask[:f]],
                               atol=0.011)


def test_stream_packing_and_buckets():
    seqs = []
    for i, f in enumerate([30, 10, 20, 40]):
        cfg = synthetic.SceneConfig(num_frames=f, max_objects=4, seed=i)
        _, _, db, dm = synthetic.generate_scene(cfg)
        seqs.append((f"s{i}", db, dm))
    batch = stream.pack(seqs, pad_multiple=8)
    assert batch.det_boxes.shape[0] == 40          # longest
    assert batch.det_boxes.shape[1] == 8           # padded stream axis
    assert batch.frame_valid[:10, 1].all() and not batch.frame_valid[10:, 1].any()
    buckets = stream.length_buckets(seqs, num_buckets=2)
    assert len(buckets) == 2
    lens0 = [s[1].shape[0] for s in buckets[0]]
    lens1 = [s[1].shape[0] for s in buckets[1]]
    assert max(lens0) <= min(lens1)
    rep = stream.replicate(seqs, 7)
    assert len(rep) == 28  # paper §VI: 11 files x 7


def test_stream_pack_edge_cases():
    """Ragged-path regressions: empty input, zero/single-frame sequences,
    and pad_multiple rounding (surfaced by the ragged scheduler)."""
    # empty sequence list -> well-formed empty batch
    empty = stream.pack([], max_dets=5)
    assert empty.det_boxes.shape == (0, 0, 5, 4)
    assert empty.det_mask.shape == (0, 0, 5)
    assert empty.names == ()

    # single-frame and zero-frame sequences pack like any other length
    one = ("one", np.ones((1, 2, 4), np.float32), np.ones((1, 2), bool))
    zero = ("zero", np.zeros((0, 2, 4), np.float32), np.zeros((0, 2), bool))
    batch = stream.pack([one, zero])
    assert batch.det_boxes.shape == (1, 2, 2, 4)
    assert batch.frame_valid[:, 0].all() and not batch.frame_valid[:, 1].any()

    # pad_multiple never shrinks an aligned S, rounds an unaligned one up
    four = [(f"s{i}", np.ones((2, 1, 4), np.float32), np.ones((2, 1), bool))
            for i in range(4)]
    assert stream.pack(four, pad_multiple=2).det_boxes.shape[1] == 4
    assert stream.pack(four[:3], pad_multiple=2).det_boxes.shape[1] == 4
    assert stream.pack(four[:1], pad_multiple=8).det_boxes.shape[1] == 8
    with np.testing.assert_raises(ValueError):
        stream.pack(four, pad_multiple=0)


def test_length_buckets_edge_cases():
    """No empty buckets, ever: fewer sequences than buckets yields one
    sequence per bucket; an empty input yields no buckets."""
    assert stream.length_buckets([], num_buckets=4) == []
    seqs = [(f"s{i}", np.ones((f, 1, 4), np.float32), np.ones((f, 1), bool))
            for i, f in enumerate([9, 3])]
    buckets = stream.length_buckets(seqs, num_buckets=4)
    assert [len(b) for b in buckets] == [1, 1]
    assert buckets[0][0][0] == "s1"                 # sorted by length
    with np.testing.assert_raises(ValueError):
        stream.length_buckets(seqs, num_buckets=0)


def test_reorder_buffer_releases_in_submission_order():
    rb = stream.ReorderBuffer()
    rb.put(1, "b")
    rb.put(2, "c")
    assert rb.pop_ready() == []                     # 0 still outstanding
    rb.put(0, "a")
    assert rb.pop_ready() == ["a", "b", "c"]
    assert len(rb) == 0
    rb.put(3, "d")
    assert rb.pop_ready() == ["d"]
    with np.testing.assert_raises(ValueError):
        rb.put(3, "dup")                            # already released


def test_table_i_constants():
    assert len(mot.TABLE_I) == 11
    assert sum(f for f, _ in mot.TABLE_I.values()) == 5500  # paper Table VI


def test_token_stream_learnable():
    ts = tokens.TokenStream(vocab_size=100, seed=0)
    b = ts.batch(4, 64)
    assert b["tokens"].shape == (4, 64) and b["labels"].shape == (4, 64)
    # bigram structure: most transitions follow the table
    follow = (ts._next[b["tokens"]] == b["labels"]).mean()
    assert follow > 0.8


def test_audio_and_vision_batches():
    rng = np.random.default_rng(0)
    ab = tokens.audio_batch(rng, 2, 128, 16, 50, mask_rate=0.3)
    assert ab["feats"].shape == (2, 128, 16)
    assert ab["mask_spans"].any() and not ab["mask_spans"].all()
    ts = tokens.TokenStream(100)
    vb = tokens.vision_batch(rng, 2, 24, 4, 8, 100, ts)
    assert vb["patches"].shape == (2, 4, 8)
    assert vb["tokens"].shape == (2, 24)


def test_mot15_empty_det_file_roundtrip(tmp_path):
    """Regression: an empty / whitespace-only det file used to crash
    np.loadtxt; it now parses to a well-formed zero-frame batch, and
    write_det_file of that batch round-trips through read_det_file."""
    for raw in ("", "\n", "   \n\t\n"):
        db, dm = mot.read_det_file(io.StringIO(raw))
        assert db.shape == (0, 1, 4) and db.dtype == np.float32
        assert dm.shape == (0, 1) and dm.dtype == bool
    # round-trip the zero-frame batch through a real file
    p = tmp_path / "det.txt"
    mot.write_det_file(p, np.zeros((0, 1, 4), np.float32),
                       np.zeros((0, 1), bool))
    rb, rm = mot.read_det_file(p)
    assert rb.shape == (0, 1, 4) and rm.shape == (0, 1)
    # frames with no surviving detections (all-False mask) also read back
    mot.write_det_file(p, np.zeros((3, 2, 4), np.float32),
                       np.zeros((3, 2), bool))
    rb, rm = mot.read_det_file(p)
    assert rm.sum() == 0


def test_mot15_min_conf_filters_everything(tmp_path):
    """All rows below min_conf used to hit frames.max() on an empty
    array; now: the zero-frame batch."""
    txt = "1,-1,10,10,20,20,0.1,-1,-1,-1\n2,-1,5,5,10,10,0.2,-1,-1,-1\n"
    rb, rm = mot.read_det_file(io.StringIO(txt), min_conf=0.5)
    assert rb.shape == (0, 1, 4) and rm.shape == (0, 1)


# ------------------------------------- class / conf columns (DESIGN.md §10)
def test_mot15_class_conf_columns_roundtrip(tmp_path):
    """write_det_file(det_class=, det_conf=) -> read_det_file(with_extras=
    True) round-trips classes exactly and float32 confidences bit-exactly
    (``%.9g`` is lossless for float32)."""
    rng = np.random.default_rng(9)
    det_boxes = np.round(rng.uniform(0, 400, (5, 3, 4)).astype(np.float32), 2)
    det_boxes[..., 2:] = det_boxes[..., :2] + 10.0
    det_mask = rng.random((5, 3)) < 0.8
    det_mask[4, 0] = True                            # keep frame 5 present
    det_class = rng.integers(0, 7, (5, 3)).astype(np.int32)
    det_conf = rng.random((5, 3)).astype(np.float32)  # awkward mantissas
    p = tmp_path / "det.txt"
    mot.write_det_file(p, det_boxes, det_mask, det_class=det_class,
                       det_conf=det_conf)
    rb, rm, rc, rconf = mot.read_det_file(p, with_extras=True)
    np.testing.assert_array_equal(rm.sum(1), det_mask.sum(1))
    np.testing.assert_array_equal(rc[rm], det_class[det_mask])
    np.testing.assert_array_equal(rconf[rm], det_conf[det_mask])  # bit-exact


def test_mot15_default_write_is_classless(tmp_path):
    """Without det_class/det_conf the writer emits the pre-§10 byte layout
    (conf=1, class=-1) and the extras reader reports class -1 / conf 1."""
    det_boxes = np.array([[[10.0, 20.0, 40.0, 80.0]]], np.float32)
    det_mask = np.ones((1, 1), bool)
    p = tmp_path / "det.txt"
    mot.write_det_file(p, det_boxes, det_mask)
    assert p.read_text() == "1,-1,10.00,20.00,30.00,60.00,1,-1,-1,-1\n"
    rb, rm, rc, rconf = mot.read_det_file(p, with_extras=True)
    assert int(rc[0, 0]) == -1 and float(rconf[0, 0]) == 1.0


def test_mot15_extras_empty_shapes():
    """with_extras=True keeps the zero-frame contract: (0,1)-shaped class
    and conf arrays alongside the empty boxes/mask."""
    for raw in ("", "\n", "1,-1,1,1,2,2,0.1,3,-1,-1\n"):
        db, dm, dc, dconf = mot.read_det_file(
            io.StringIO(raw), min_conf=0.5, with_extras=True)
        assert db.shape == (0, 1, 4) and dm.shape == (0, 1)
        assert dc.shape == (0, 1) and dc.dtype == np.int32
        assert dconf.shape == (0, 1) and dconf.dtype == np.float32


def test_multiclass_scene_class_stable_and_one_hot():
    """Generator invariants the parity tests lean on: per-object classes
    never change along a trajectory, embeddings are one-hot (dot products
    exactly 0/1), and true detections inherit their object's class."""
    cfg = synthetic.SceneConfig(num_frames=30, max_objects=5, seed=2,
                                det_noise=0.0, fp_rate=0.0, miss_rate=0.0)
    gtb, gtm, gtc, db, dm, dc, de = synthetic.generate_multiclass_scene(
        cfg, num_classes=3, embed_dim=4)
    assert gtc.shape == (gtm.shape[1],) and gtc.dtype == np.int32
    assert (0 <= gtc).all() and (gtc < 3).all()
    v = de[dm]
    assert set(np.unique(v)) <= {0.0, 1.0}
    np.testing.assert_array_equal(v.sum(-1), np.ones(len(v)))  # one-hot
    # with no noise/misses/FPs every detection is some gt box verbatim:
    # its class must equal that object's class in every frame
    for t in range(db.shape[0]):
        for d in np.where(dm[t])[0]:
            i = int(np.argmin(np.abs(gtb[t] - db[t, d]).sum(-1)))
            assert gtm[t, i] and dc[t, d] == gtc[i], (t, d)


def test_crossing_scene_geometry():
    """Objects start on a circle and pass through the center: by
    mid-sequence some cross-class pair overlaps (the ambiguity the class
    partition must resolve), classes alternate round-robin, and dropout
    stays seeded-deterministic."""
    from repro.core.ref_numpy import iou

    gtb, gtm, cls, db, dm, dc, de = synthetic.generate_crossing_scene(
        num_frames=41, num_objects=4, num_classes=2)
    np.testing.assert_array_equal(cls, [0, 1, 0, 1])
    assert gtm.all() and dm.all()                    # no dropout by default
    mid = np.array([[iou(a, b) for b in gtb[20]] for a in gtb[20]])
    cross = cls[:, None] != cls[None, :]
    assert (mid[cross] > 0.5).any()                  # cross-class overlap
    a = synthetic.generate_crossing_scene(seed=5, miss_rate=0.3)
    b = synthetic.generate_crossing_scene(seed=5, miss_rate=0.3)
    assert 0 < a[4].sum() < a[1].size                # dropout happened
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)          # seeded-deterministic
