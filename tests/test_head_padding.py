"""head_pad_factor exactness: the padded model computes the SAME function.

x-factor padding preserves the GQA grouping ``i // g``; the padded block is
zero-initialized and the o-proj rows of padded heads are zero, so forward,
prefill and decode outputs must match the unpadded model bit-for-bit (fp32).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.models.transformer import Parallel


def _graft(t0, t2):
    """Copy real-head weights into the padded param tree (pad stays zero)."""
    if isinstance(t0, dict):
        return {k: _graft(t0[k], t2[k]) for k in t0}
    if isinstance(t0, list):
        return [_graft(a, b) for a, b in zip(t0, t2)]
    if t0.shape == t2.shape:
        return t0
    z = jnp.zeros_like(t2)
    return z.at[tuple(slice(0, s) for s in t0.shape)].set(t0)


def test_padded_model_is_identical():
    cfg0 = ModelConfig(num_layers=2, d_model=64, n_heads=5, n_kv_heads=1,
                       head_dim=8, d_ff=128, vocab_size=100, max_seq_len=64,
                       dtype="float32", qkv_bias=True)
    cfg2 = dataclasses.replace(cfg0, head_pad_factor=2)
    m0, m2 = build_model(cfg0), build_model(cfg2)
    p0, _ = m0.init(jax.random.PRNGKey(0))
    p2, _ = m2.init(jax.random.PRNGKey(0))
    p2 = _graft(p0, p2)
    batch = {"tokens": (jnp.arange(32).reshape(2, 16) * 7) % 100}

    f0 = m0.forward(p0, batch)
    f2 = m2.forward(p2, batch)
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f2))

    lg0, c0 = m0.prefill(p0, batch, Parallel(), 32)
    lg2, c2 = m2.prefill(p2, batch, Parallel(), 32)
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg2))
    # cache stores only real kv heads in both models
    leaf0 = jax.tree.leaves(c0)[0]
    leaf2 = jax.tree.leaves(c2)[0]
    assert leaf0.shape == leaf2.shape

    for t in (16, 17):
        tok = batch["tokens"][:, :1]
        d0, c0 = m0.decode(p0, tok, jnp.full((2,), t, jnp.int32), c0)
        d2, c2 = m2.decode(p2, tok, jnp.full((2,), t, jnp.int32), c2)
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d2))


def test_grouping_preserved():
    """x2 padding keeps g = n_heads / n_kv_heads, so head i -> kv i//g."""
    cfg = ModelConfig(n_heads=40, n_kv_heads=8, head_pad_factor=2)
    assert cfg.eff_n_heads == 80 and cfg.eff_n_kv_heads == 16
    assert cfg.eff_n_heads // cfg.eff_n_kv_heads == cfg.n_heads // cfg.n_kv_heads
