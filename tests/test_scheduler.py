"""Online multi-stream scheduler: ragged lane recycling (DESIGN.md §3).

The load-bearing invariant: a sequence multiplexed through recycled lanes
emits tracks **bit-identical** to running it alone — on both engine paths.
Plus: FIFO admission-order fairness, in-order drain at shutdown, reuse
after drain, and degenerate sequences (single-frame, empty).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import SortConfig, SortEngine
from repro.data.synthetic import SceneConfig, generate_scene
from repro.serve import StreamScheduler

# one detection budget for every test so jit caches are shared
MAX_DETS = 7
_SOLO: dict = {}


def _scene(seed, frames):
    _, _, db, dm = generate_scene(
        SceneConfig(num_frames=frames, max_objects=4, seed=seed))
    d = db.shape[1]
    assert d <= MAX_DETS, d
    return (np.pad(db, ((0, 0), (0, MAX_DETS - d), (0, 0))),
            np.pad(dm, ((0, 0), (0, MAX_DETS - d))))


def _engine(use_kernels, chunk_kernel=False):
    return SortEngine(SortConfig(max_trackers=8, max_detections=MAX_DETS,
                                 use_kernels=use_kernels,
                                 chunk_kernel=chunk_kernel))


def _solo_run(eng, db, dm):
    key = (db.shape[0], eng.config.use_kernels)
    if key not in _SOLO:
        _SOLO[key] = jax.jit(eng.run)
    _, out = _SOLO[key](eng.init(1), jnp.asarray(db)[:, None],
                        jnp.asarray(dm)[:, None])
    return out


def _assert_tracks_equal_solo(tracks, solo, ctx=""):
    np.testing.assert_array_equal(tracks.uid, np.asarray(solo.uid[:, 0]),
                                  err_msg=f"uid {ctx}")
    np.testing.assert_array_equal(tracks.emit, np.asarray(solo.emit[:, 0]),
                                  err_msg=f"emit {ctx}")
    np.testing.assert_array_equal(tracks.boxes, np.asarray(solo.boxes[:, 0]),
                                  err_msg=f"boxes {ctx}")


# ------------------------------------------------------ recycling exactness
@pytest.mark.parametrize("use_kernels", [False, True])
def test_ragged_mix_bit_identical_to_solo_runs(use_kernels):
    """Six ragged sequences through a 3-lane scheduler (lanes recycled
    mid-run) emit tracks bit-identical to per-sequence solo runs."""
    lengths = [12, 5, 9, 5, 12, 1]
    seqs = [(f"s{i}", *_scene(i, f)) for i, f in enumerate(lengths)]
    eng = _engine(use_kernels)
    sched = StreamScheduler(eng, num_lanes=3, chunk=4)
    for name, db, dm in seqs:
        sched.submit(name, db, dm)
    results = sched.run()
    assert [r.name for r in results] == [s[0] for s in seqs]
    assert not sched.busy
    for (name, db, dm), tracks in zip(seqs, results):
        assert tracks.num_frames == db.shape[0]
        _assert_tracks_equal_solo(tracks, _solo_run(eng, db, dm),
                                  f"{name} uk={use_kernels}")


@pytest.mark.parametrize("use_kernels", [False, True])
def test_lane_budget_smaller_than_traffic(use_kernels):
    """More waiting sequences than lanes: a single lane serializes five
    sequences through the same recycled slot, still bit-exact."""
    lengths = [5, 9, 1, 12, 5]
    seqs = [(f"q{i}", *_scene(10 + i, f)) for i, f in enumerate(lengths)]
    eng = _engine(use_kernels)
    sched = StreamScheduler(eng, num_lanes=1, chunk=5)
    for name, db, dm in seqs:
        sched.submit(name, db, dm)
    results = sched.run()
    assert [r.name for r in results] == [s[0] for s in seqs]
    for (name, db, dm), tracks in zip(seqs, results):
        _assert_tracks_equal_solo(tracks, _solo_run(eng, db, dm),
                                  f"{name} uk={use_kernels}")


# ------------------------------------------------------- admission fairness
def test_admission_order_is_fifo():
    """Lanes admit strictly in submission order, and admission steps are
    monotone: a later submission never jumps an earlier one."""
    lengths = [6, 6, 2, 2, 2, 2]
    eng = _engine(True)
    sched = StreamScheduler(eng, num_lanes=2, chunk=4)
    for i, f in enumerate(lengths):
        sched.submit(f"a{i}", *_scene(i, f))
    sched.run()
    admitted = [idx for idx, _ in sched.admissions]
    steps = [step for _, step in sched.admissions]
    assert admitted == list(range(len(lengths)))
    assert steps == sorted(steps)
    # first two sequences go straight into the two free lanes at step 0
    assert steps[:2] == [0, 0]


def test_recycle_admits_in_the_freed_step():
    """A lane freed at step t admits the next sequence at step t+1 — the
    masked re-init and the new sequence's first frame share that step (no
    idle step between back-to-back sequences on one lane)."""
    eng = _engine(True)
    sched = StreamScheduler(eng, num_lanes=1, chunk=8)
    sched.submit("first", *_scene(0, 5))
    sched.submit("second", *_scene(1, 3))
    sched.run()
    assert sched.admissions == [(0, 0), (1, 5)]


# ------------------------------------------------------------------- drain
def test_drain_emits_in_submission_order():
    """A short sequence submitted after a long one *finishes* first but is
    *released* second: drain order is submission order."""
    eng = _engine(True)
    long = _scene(3, 14)
    short = _scene(4, 2)
    sched = StreamScheduler(eng, num_lanes=2, chunk=4)
    sched.submit("long", *long)
    sched.submit("short", *short)
    results = sched.run()
    assert [r.name for r in results] == ["long", "short"]
    _assert_tracks_equal_solo(results[0], _solo_run(eng, *long), "long")
    _assert_tracks_equal_solo(results[1], _solo_run(eng, *short), "short")


def test_scheduler_reusable_after_drain():
    """submit() after run() keeps working; recycled lanes start every new
    admission from a masked re-init, so earlier traffic cannot leak."""
    eng = _engine(True)
    db, dm = _scene(5, 9)
    sched = StreamScheduler(eng, num_lanes=2, chunk=4)
    sched.submit("warm", *_scene(6, 12))
    sched.run()
    sched.submit("later", db, dm)
    (tracks,) = sched.run()
    _assert_tracks_equal_solo(tracks, _solo_run(eng, db, dm), "later")


def test_empty_and_single_frame_sequences():
    eng = _engine(True)
    sched = StreamScheduler(eng, num_lanes=2, chunk=4)
    db1, dm1 = _scene(7, 1)
    sched.submit("empty", np.zeros((0, MAX_DETS, 4), np.float32),
                 np.zeros((0, MAX_DETS), bool))
    sched.submit("one", db1, dm1)
    results = sched.run()
    assert [r.name for r in results] == ["empty", "one"]
    assert results[0].num_frames == 0 and results[0].emit.shape[1] == 8
    _assert_tracks_equal_solo(results[1], _solo_run(eng, db1, dm1), "one")


def test_empty_run_returns_nothing():
    sched = StreamScheduler(_engine(True), num_lanes=2, chunk=4)
    assert sched.run() == []
    assert not sched.busy


def test_rejects_oversized_detection_rows():
    sched = StreamScheduler(_engine(True), num_lanes=1)
    with pytest.raises(ValueError):
        sched.submit("big", np.zeros((3, MAX_DETS + 1, 4), np.float32),
                     np.zeros((3, MAX_DETS + 1), bool))


# ------------------------------------------------------- property coverage
@pytest.mark.slow
@settings(max_examples=6, deadline=None, derandomize=True)
@given(lengths=st.lists(st.sampled_from([1, 5, 9, 12]), min_size=1,
                        max_size=7),
       num_lanes=st.integers(1, 3))
def test_scheduler_exactness_property(lengths, num_lanes):
    """Any ragged length mix over any lane budget stays bit-identical to
    solo runs (fused path; lengths drawn from a fixed set so hypothesis
    examples share the solo-run jit cache)."""
    seqs = [(f"p{i}", *_scene(20 + i, f)) for i, f in enumerate(lengths)]
    eng = _engine(True)
    sched = StreamScheduler(eng, num_lanes=num_lanes, chunk=4)
    for name, db, dm in seqs:
        sched.submit(name, db, dm)
    results = sched.run()
    assert [r.name for r in results] == [s[0] for s in seqs]
    for (name, db, dm), tracks in zip(seqs, results):
        _assert_tracks_equal_solo(tracks, _solo_run(eng, db, dm), name)


# ------------------------------------------------- stranded-result draining
def test_zero_frame_sequence_is_not_stranded():
    """Regression: a zero-frame sequence submitted while the scheduler is
    idle finalizes straight into the reorder buffer, but `busy` ignored
    buffered results and results only popped inside the chunk path — the
    documented `while sched.busy` drain loop never surfaced it."""
    sched = StreamScheduler(_engine(True), num_lanes=2, chunk=4)
    sched.submit("empty", np.zeros((0, MAX_DETS, 4), np.float32),
                 np.zeros((0, MAX_DETS), bool))
    assert sched.busy                       # was False before the fix
    got = sched.pop_ready()                 # no dispatch required
    assert [t.name for t in got] == ["empty"]
    assert got[0].num_frames == 0
    assert not sched.busy
    assert sched.chunks_run == 0            # nothing was ever dispatched


def test_drain_releases_buffered_results_without_empty_chunk():
    """drain() surfaces buffered zero-frame results alongside real work,
    in submission order, and never dispatches an empty chunk for them."""
    eng = _engine(True)
    db, dm = _scene(8, 5)
    sched = StreamScheduler(eng, num_lanes=2, chunk=4)
    sched.submit("empty0", np.zeros((0, MAX_DETS, 4), np.float32),
                 np.zeros((0, MAX_DETS), bool))
    sched.submit("real", db, dm)
    results = sched.drain()
    assert [t.name for t in results] == ["empty0", "real"]
    _assert_tracks_equal_solo(results[1], _solo_run(eng, db, dm), "real")
    chunks_for_real = sched.chunks_run
    # drain again with only a buffered result: no new chunk may run
    sched.submit("empty1", np.zeros((0, MAX_DETS, 4), np.float32),
                 np.zeros((0, MAX_DETS), bool))
    (only,) = sched.drain()
    assert only.name == "empty1"
    assert sched.chunks_run == chunks_for_real
    assert not sched.busy


# ------------------------------------------------------------- uid headroom
@pytest.mark.parametrize("use_kernels", [False, True])
def test_uid_guard_trips_before_int32_overflow(use_kernels):
    """A lane whose uid counter crosses slots.UID_LIMIT mid-sequence must
    fail loudly (silent int32 wraparound could alias live track ids)."""
    from repro.core import slots

    eng = _engine(use_kernels)
    sched = StreamScheduler(eng, num_lanes=1, chunk=4)
    sched.submit("monster", *_scene(30, 8))
    sched._run_chunk()                       # first 4 frames, uids live
    st = sched._state
    sched._state = st._replace(pool=st.pool._replace(
        next_uid=jnp.full_like(st.pool.next_uid, slots.UID_LIMIT + 1)))
    with pytest.raises(RuntimeError, match="uid counter"):
        sched.run()


@pytest.mark.parametrize("use_kernels", [False, True])
def test_recycled_lane_never_reuses_a_live_uid(use_kernels):
    """Lane recycling resets the uid namespace: after reset_ragged the
    recycled lane holds no live uid and its counter restarts at
    uid_start, while the other lane's uids and counter are untouched —
    so a new sequence's ids can never collide with live trackers."""
    from repro.core import sort as sort_mod

    eng = _engine(use_kernels)
    state = eng.init_ragged(2)
    db, dm = _scene(31, 6)
    both = jnp.asarray(np.stack([db, db], axis=1))
    masks = jnp.asarray(np.stack([dm, dm], axis=1))
    active = jnp.ones((2,), bool)
    for f in range(6):                       # populate live uids on both
        state, _ = eng.step_ragged(state, both[f], masks[f], active)
    pool_before = jax.device_get(state.pool)
    reset = jnp.asarray(np.array([True, False]))
    state = sort_mod.reset_ragged(state, reset)
    pool = jax.device_get(state.pool)
    uid = pool.uid if not use_kernels else pool.uid.T      # -> [lanes, T]
    uid_before = (pool_before.uid if not use_kernels
                  else pool_before.uid.T)
    assert (uid_before[0] >= 1).any()        # lane 0 really had live uids
    assert (uid[0] == -1).all()              # ...all cleared by the reset
    assert int(pool.next_uid[0]) == 1        # fresh namespace
    np.testing.assert_array_equal(uid[1], uid_before[1])   # lane 1 intact
    assert int(pool.next_uid[1]) == int(pool_before.next_uid[1])


# ------------------------------------------- chunk-kernel dispatch mode
def test_chunk_kernel_results_and_accounting_match_per_frame_mode():
    """The megakernel dispatch mode (DESIGN.md §9) is invisible to the
    scheduler: same traffic through chunk_kernel=True and =False yields
    bit-identical tracks AND an identical accounting tuple (frames,
    lane-steps, chunks, utilization, admission schedule).  The mix forces
    a ragged tail chunk (lengths not divisible by chunk=7) and mid-chunk
    lane recycles."""
    lengths = [12, 5, 9, 3]
    seqs = [(f"ck{i}", *_scene(40 + i, f)) for i, f in enumerate(lengths)]
    accounting = {}
    results = {}
    for chunk_kernel in (False, True):
        sched = StreamScheduler(_engine(True, chunk_kernel=chunk_kernel),
                                num_lanes=2, chunk=7)
        for name, db, dm in seqs:
            sched.submit(name, db, dm)
        results[chunk_kernel] = sched.run()
        accounting[chunk_kernel] = (sched.frames_processed,
                                    sched.lane_steps, sched.chunks_run,
                                    sched.utilization,
                                    list(sched.admissions))
    assert accounting[False] == accounting[True]
    for ra, rb in zip(results[False], results[True]):
        assert ra.name == rb.name
        np.testing.assert_array_equal(ra.uid, rb.uid, err_msg=ra.name)
        np.testing.assert_array_equal(ra.emit, rb.emit, err_msg=ra.name)
        np.testing.assert_array_equal(ra.boxes, rb.boxes, err_msg=ra.name)
    # and both modes stay bit-identical to per-sequence solo runs
    eng = _engine(True)
    for (name, db, dm), tracks in zip(seqs, results[True]):
        _assert_tracks_equal_solo(tracks, _solo_run(eng, db, dm),
                                  f"{name} (megakernel)")


# --------------------------------------------------- utilization accounting
def test_lane_steps_exclude_fully_idle_drain_tail():
    """Regression: the utilization denominator used to count the
    fully-idle tail steps of a draining chunk (`chunk * num_lanes` per
    chunk); it must come from the planned `active` mask instead."""
    eng = _engine(False)
    sched = StreamScheduler(eng, num_lanes=2, chunk=8)
    db, dm = _scene(0, frames=3)
    sched.submit("only", db, dm)
    (tracks,) = sched.run()
    assert tracks.boxes.shape[0] == 3
    assert sched.frames_processed == 3
    # one chunk ran; only its first 3 steps carried any work
    assert sched.chunks_run == 1
    assert sched.lane_steps == 3 * 2          # not 8 * 2
    assert sched.utilization == pytest.approx(3 / 6)


def test_utilization_full_when_lanes_saturated():
    """Two equal-length sequences on two lanes: every working step is
    fully occupied, so utilization is exactly 1."""
    eng = _engine(False)
    sched = StreamScheduler(eng, num_lanes=2, chunk=4)
    for i in range(2):
        db, dm = _scene(i, frames=8)
        sched.submit(f"s{i}", db, dm)
    sched.run()
    assert sched.frames_processed == 16
    assert sched.lane_steps == 16
    assert sched.utilization == 1.0


# --------------------------------------------- checkpoint/restore hooks
@pytest.mark.parametrize("use_kernels", [False, True])
def test_export_import_midrun_roundtrip(use_kernels):
    """export_state at a chunk boundary, import into a FRESH scheduler,
    continue: the combined output stream equals an uninterrupted run and
    every sequence stays bit-identical to its solo run (DESIGN.md §11)."""
    eng = _engine(use_kernels)
    seqs = [(f"s{i}", *_scene(i, frames=f))
            for i, f in enumerate([17, 30, 9, 23])]

    sched = StreamScheduler(eng, num_lanes=2, chunk=8)
    for name, db, dm in seqs:
        sched.submit(name, db, dm)
    results = []
    for _ in range(2):
        results.extend(sched.run_chunk())
    meta, arrays = sched.export_state()
    import json
    json.dumps(meta)                    # the meta half must be JSON-able

    fresh = StreamScheduler(_engine(use_kernels), num_lanes=2, chunk=8)
    fresh.import_state(meta, arrays)
    assert fresh.chunks_run == sched.chunks_run
    while fresh.busy:
        results.extend(fresh.run_chunk())
    assert [t.name for t in results] == [n for n, _, _ in seqs]
    for (name, db, dm), tracks in zip(seqs, results):
        _assert_tracks_equal_solo(tracks, _solo_run(eng, db, dm), name)


def test_export_import_preserves_held_reorder_results():
    """A finished-but-unreleased completion (parked above the reorder
    watermark) must cross the checkpoint and release in order."""
    eng = _engine(False)
    sched = StreamScheduler(eng, num_lanes=2, chunk=8)
    long = _scene(0, frames=30)
    short = _scene(1, frames=4)
    sched.submit("long", *long)
    sched.submit("short", *short)       # finishes first, held for "long"
    out = sched.run_chunk()
    assert out == [] and len(sched._ready) == 1
    meta, arrays = sched.export_state()
    fresh = StreamScheduler(_engine(False), num_lanes=2, chunk=8)
    fresh.import_state(meta, arrays)
    results = []
    while fresh.busy:
        results.extend(fresh.run_chunk())
    assert [t.name for t in results] == ["long", "short"]
    _assert_tracks_equal_solo(results[1], _solo_run(eng, *short), "short")


def test_import_rejects_mismatched_engine_and_width():
    eng = _engine(False)
    sched = StreamScheduler(eng, num_lanes=2, chunk=8)
    db, dm = _scene(0, frames=6)
    sched.submit("s", db, dm)
    sched.run_chunk()
    meta, arrays = sched.export_state()

    other = SortEngine(SortConfig(max_trackers=8, max_detections=MAX_DETS,
                                  iou_threshold=0.5))
    with pytest.raises(ValueError, match="engine config"):
        StreamScheduler(other, num_lanes=2, chunk=8).import_state(
            meta, arrays)
    with pytest.raises(ValueError, match="ladder"):
        StreamScheduler(_engine(False), num_lanes=4, chunk=8).import_state(
            meta, arrays)
    with pytest.raises(ValueError, match="schema"):
        StreamScheduler(_engine(False), num_lanes=2, chunk=8).import_state(
            {**meta, "schema": 99}, arrays)
    lane_key = next(k for k in arrays if k.startswith("lane/"))
    broken = {k: v for k, v in arrays.items() if k != lane_key}
    with pytest.raises(ValueError, match="missing device-state"):
        StreamScheduler(_engine(False), num_lanes=2, chunk=8).import_state(
            meta, broken)
