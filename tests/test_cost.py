"""Pluggable cost composition + class-partitioned matching (DESIGN.md §10).

Unit coverage for ``core.cost`` and the block-diagonal masking claim:

* spec validation / composition helpers,
* the lane-major and batch-major evaluators are bit-identical term for
  term (the same contract ``associate`` / ``associate_lane`` share),
* the class-partition ``pair_mask`` makes ONE masked Hungarian solve
  exactly equivalent to solving each class's sub-problem separately with
  scipy — the no-per-class-loop argument, verified not argued,
* the closed-form Mahalanobis term matches a plain numpy computation.
"""
from functools import partial

import numpy as np
import pytest

from repro.core import cost as cost_mod
from repro.core.cost import CostSpec


# ---------------------------------------------------------------- spec logic
def test_costspec_validation():
    with pytest.raises(ValueError, match="embed_dim"):
        CostSpec(embed_weight=0.5)            # embed term needs a width
    with pytest.raises(ValueError, match="embed_dim"):
        CostSpec(embed_dim=-1)
    with pytest.raises(ValueError, match="maha_gate"):
        CostSpec(maha_gate=0.0)
    with pytest.raises(ValueError, match="unknown cost"):
        cost_mod.parse_cost("euclidean")


def test_costspec_flags_and_bit_identity_contract():
    assert cost_mod.IOU.is_iou_only
    assert not cost_mod.needs_score(cost_mod.IOU)
    assert not cost_mod.needs_feasible(cost_mod.IOU, num_classes=1)
    # the pure-IoU single-class config must hand the solvers exactly the
    # pre-cost arguments: score=None, feasible=None
    sc, fe = cost_mod.score_and_feasible_batch(
        np.zeros((2, 3)), cost_mod.IOU, num_classes=1)
    assert sc is None and fe is None

    maha = cost_mod.iou_maha()
    assert maha.uses_maha and not cost_mod.needs_score(maha)
    assert cost_mod.needs_feasible(maha, num_classes=1)

    emb = cost_mod.iou_embed(8)
    assert emb.uses_embed and cost_mod.needs_score(emb)
    assert not cost_mod.needs_feasible(emb, num_classes=1)
    assert cost_mod.needs_feasible(emb, num_classes=3)

    assert cost_mod.parse_cost("iou") is cost_mod.IOU
    assert cost_mod.parse_cost("iou+maha").uses_maha
    assert cost_mod.parse_cost("iou+embed", embed_dim=6).embed_dim == 6

    # frozen + hashable: rides through jit static arguments
    assert hash(emb) == hash(cost_mod.iou_embed(8))


def test_costspec_is_jit_static_safe():
    import jax

    calls = []

    @partial(jax.jit, static_argnames="spec")
    def f(x, *, spec: CostSpec):
        calls.append(spec)
        return x * spec.iou_weight

    f(np.ones(2), spec=cost_mod.IOU)
    f(np.ones(2), spec=cost_mod.IOU)          # cache hit, no retrace
    assert len(calls) == 1
    f(np.ones(2), spec=CostSpec(iou_weight=0.5))
    assert len(calls) == 2


# ------------------------------------------------- lane vs batch bit-parity
def _random_inputs(rng, d=5, t=4, lanes=3, e=6):
    """One random problem in BOTH layouts (batch [L, ...DT], lane [..DT, L])."""
    iou_b = rng.random((lanes, d, t)).astype(np.float32)
    dc_b = rng.integers(0, 3, (lanes, d)).astype(np.int32)
    tc_b = rng.integers(0, 3, (lanes, t)).astype(np.int32)
    de_b = rng.normal(size=(lanes, d, e)).astype(np.float32)
    te_b = rng.normal(size=(lanes, t, e)).astype(np.float32)
    z_b = rng.normal(size=(lanes, d, 4)).astype(np.float32) * 10
    x_b = rng.normal(size=(lanes, t, 7)).astype(np.float32) * 10
    a = rng.normal(size=(lanes, t, 4, 4)).astype(np.float32)
    p4_b = a @ a.transpose(0, 1, 3, 2) + 3 * np.eye(4, dtype=np.float32)
    lane = dict(
        iou=iou_b.transpose(1, 2, 0),
        det_class=dc_b.T, trk_cls=tc_b.T,
        det_embed=de_b.transpose(1, 2, 0),
        trk_embed=te_b.transpose(2, 1, 0),
        z_det=z_b.transpose(2, 1, 0),
        x_pred=x_b.transpose(2, 1, 0),
        p4_pred=[[p4_b[:, :, i, j].T for j in range(4)] for i in range(4)])
    batch = dict(iou=iou_b, det_class=dc_b, trk_cls=tc_b, det_embed=de_b,
                 trk_embed=te_b, z_det=z_b, x_pred=x_b, p4_pred=p4_b)
    return batch, lane


@pytest.mark.parametrize("spec,nc", [
    (cost_mod.iou_embed(6), 1),
    (cost_mod.iou_maha(), 3),
    (CostSpec(maha_gate=cost_mod.CHI2_GATE_4DOF, embed_weight=0.5,
              embed_dim=6), 3),
])
def test_lane_and_batch_evaluators_bit_identical(spec, nc):
    """Same floats, same gate booleans, in either layout — the property
    that lets the fused kernels and the per-phase path share one oracle."""
    batch, lane = _random_inputs(np.random.default_rng(0))
    kw_b = {k: v for k, v in batch.items() if k != "iou"}
    kw_l = {k: v for k, v in lane.items() if k != "iou"}
    sc_b, fe_b = cost_mod.score_and_feasible_batch(
        batch["iou"], spec, num_classes=nc, **kw_b)
    sc_l, fe_l = cost_mod.score_and_feasible_lane(
        lane["iou"], spec, num_classes=nc, **kw_l)
    if sc_b is None:
        assert sc_l is None
    else:
        np.testing.assert_array_equal(np.asarray(sc_b),
                                      np.asarray(sc_l).transpose(2, 0, 1))
    if fe_b is None:
        assert fe_l is None
    else:
        np.testing.assert_array_equal(np.asarray(fe_b),
                                      np.asarray(fe_l).transpose(2, 0, 1))


# ------------------------------------------------------- Mahalanobis closed
def test_maha_term_matches_plain_numpy():
    """The branch-free blockwise inverse + unrolled quadratic form equals
    float64 numpy ``y @ inv(P4 + R) @ y`` within float32 tolerance."""
    from repro.kernels import ref as kref

    rng = np.random.default_rng(1)
    batch, _ = _random_inputs(rng, d=3, t=2, lanes=1)
    spec = cost_mod.iou_maha(gate=1e9)        # gate high: inspect d2 itself
    # recover d2 from the feasibility mask by bisecting the gate is silly —
    # call the internals directly instead
    p4 = [[batch["p4_pred"][..., i, j] for j in range(4)] for i in range(4)]
    sinv = cost_mod._innovation_inv(p4)
    for di in range(3):
        for ti in range(2):
            y = (batch["z_det"][0, di] - batch["x_pred"][0, ti, :4])
            s = (batch["p4_pred"][0, ti].astype(np.float64)
                 + np.diag(kref.R_DIAG))
            want = float(y.astype(np.float64) @ np.linalg.inv(s)
                         @ y.astype(np.float64))
            got = float(cost_mod._maha_terms(
                [np.float32(v) for v in y],
                [[np.asarray(sinv[i][j])[0, ti] for j in range(4)]
                 for i in range(4)]))
            assert got == pytest.approx(want, rel=2e-3), (di, ti)
    del spec


# ------------------------------------------- block-diagonal = per-class loop
def _solve_cost(score, feasible, nd, nt):
    """One masked lane solve -> set of gated (det, trk) matches."""
    import jax.numpy as jnp

    from repro.core import hungarian

    n = max(nd, nt)
    col4row = hungarian.solve_masked(
        jnp.asarray(-score), jnp.ones(nd, bool), jnp.ones(nt, bool), n,
        pair_mask=jnp.asarray(feasible))
    out = set()
    for i in range(nd):
        j = int(col4row[i])
        if j < nt and feasible[i, j]:
            out.add((i, j))
    return out


def test_single_masked_solve_equals_per_class_scipy_loop():
    """The tentpole claim verified directly: with the class-equality
    ``pair_mask`` the one padded Hungarian solve returns exactly the union
    of per-class scipy ``linear_sum_assignment`` solutions (the cost
    matrix is block-diagonal by class, so no cross-block trade can improve
    the assignment)."""
    from scipy.optimize import linear_sum_assignment

    rng = np.random.default_rng(7)
    for trial in range(20):
        nd, nt, nc = rng.integers(1, 9), rng.integers(1, 9), 3
        score = rng.random((nd, nt)).astype(np.float32)
        dc = rng.integers(0, nc, nd)
        tc = rng.integers(0, nc, nt)
        feasible = dc[:, None] == tc[None, :]
        got = _solve_cost(score, feasible, nd, nt)

        want = set()
        for c in range(nc):
            rows = np.where(dc == c)[0]
            cols = np.where(tc == c)[0]
            if rows.size == 0 or cols.size == 0:
                continue
            ri, ci = linear_sum_assignment(-score[np.ix_(rows, cols)])
            want |= {(int(rows[i]), int(cols[j])) for i, j in zip(ri, ci)}
        # identical pairs, not just identical totals: per-class blocks are
        # independent, so the optima coincide exactly (ties broken inside
        # one block cannot leak across blocks)
        tot_got = sum(score[i, j] for i, j in got)
        tot_want = sum(score[i, j] for i, j in want)
        assert tot_got == pytest.approx(tot_want, abs=1e-5), trial
        assert len(got) == len(want), trial
        assert all(dc[i] == tc[j] for i, j in got), trial
