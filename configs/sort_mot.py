"""Named ``SortConfig`` presets for the MOT15-shaped workload.

The paper's Table I sequences carry at most 13 simultaneous objects, so
every preset sizes the slot pool at ``max_trackers=16`` (a full 128-lane
stream block at the default ``block_b=2048``, DESIGN.md §2.3) and pads
detections to 16.  Pick by execution strategy:

* ``BASELINE``   — legacy per-phase engine path (pure jnp, no kernels);
  the correctness anchor everything else is bit-compared against.
* ``FUSED``      — lane-persistent fused frame path (DESIGN.md §2):
  one predict/IoU/assign/update dispatch per frame.
* ``MEGAKERNEL`` — chunk-resident megakernel (DESIGN.md §9): the fused
  path at chunk granularity — a whole planned serving chunk runs as ONE
  ``pallas_call`` with the frame loop on the kernel grid, so dispatches
  per chunk drop from F to 1.  Outputs are bit-identical to both presets
  above (tests/test_oracle_parity.py, tests/test_scheduler.py).
* ``MEGAKERNEL_GREEDY`` — megakernel with in-kernel greedy association
  (no host-side Hungarian pre-pass feeding the kernel; DESIGN.md §6).
* ``MULTICLASS``  — megakernel with the class-partitioned composed cost
  (DESIGN.md §10): 3-way class partition plus an appearance-embedding
  term, solved block-diagonally in the same single lane-batched
  assignment (cross-class pairs are masked infeasible — no per-class
  loop, no extra dispatches).  Steps take ``det_class``/``det_embed``
  operands (``SortEngine.step(..., det_class=, det_embed=)``).

Usage::

    import sys; sys.path.insert(0, "configs")
    from sort_mot import MEGAKERNEL
    from repro.core import SortEngine
    engine = SortEngine(MEGAKERNEL)
"""
from repro.core import SortConfig, cost

BASELINE = SortConfig(max_trackers=16, max_detections=16,
                      use_kernels=False)

FUSED = SortConfig(max_trackers=16, max_detections=16,
                   use_kernels=True)

MEGAKERNEL = SortConfig(max_trackers=16, max_detections=16,
                        use_kernels=True, chunk_kernel=True)

MEGAKERNEL_GREEDY = SortConfig(max_trackers=16, max_detections=16,
                               use_kernels=True, chunk_kernel=True,
                               assoc="greedy")

MULTICLASS = SortConfig(max_trackers=16, max_detections=16,
                        use_kernels=True, chunk_kernel=True,
                        cost=cost.iou_embed(embed_dim=8),
                        num_classes=3)

# ``SERVICE`` — the crash-exact serving front-end (DESIGN.md §11): the
# FUSED engine behind repro.serve.TrackingService.  The engine config is
# deliberately NOT the megakernel: checkpoints are topology-neutral, so a
# server may save under one execution strategy and resume under another —
# this preset is the conservative default, SERVICE_KNOBS the front-end
# policy (bounded admission, per-client rate limit, circuit breaker,
# chunk-boundary checkpoint cadence).
SERVICE = SortConfig(max_trackers=16, max_detections=16,
                     use_kernels=True)

SERVICE_KNOBS = {
    "max_pending": 64,          # global in-flight bound (shed beyond it)
    "per_client_pending": 16,   # per-client in-flight bound
    "rate": 100.0,              # token-bucket refill, submissions/s/client
    "burst": 20.0,              # bucket depth
    "breaker_threshold": 3,     # consecutive chunk failures to open
    "breaker_reset": 5.0,       # seconds before the half-open probe
    "ckpt_every": 1,            # checkpoint every N chunk boundaries
    "keep": 3,                  # retained checkpoints
}

PRESETS = {
    "baseline": BASELINE,
    "fused": FUSED,
    "megakernel": MEGAKERNEL,
    "megakernel-greedy": MEGAKERNEL_GREEDY,
    "multiclass": MULTICLASS,
    "service": SERVICE,
}
